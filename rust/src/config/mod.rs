//! Run configuration: a single struct covering train/score/distributed
//! runs, loadable from a JSON file (`--config run.json`) with CLI
//! overrides applied on top. This is the "real config system" the
//! launcher (`fastsvdd` binary) consumes.

use std::fmt;
use std::path::Path;

use crate::cli::Args;
use crate::distributed::{CombineMode, DistributedConfig};
use crate::error::{Error, Result};
use crate::incremental::{IncrementalConfig, ReductionConfig};
use crate::sampling::SamplingConfig;
use crate::svdd::bandwidth::AutoBandwidth;
use crate::svdd::trainer::SvddParams;
use crate::svdd::{Kernel, Wss};
use crate::util::json::Json;

pub use crate::parallel::{ParallelismConfig, ThreadCount};

/// Which training algorithm to run. Every variant is served by a
/// [`crate::engine::Trainer`] registered in
/// [`crate::engine::trainer_for`], so consumers construct and run all
/// methods uniformly through [`crate::engine::Engine`].
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Method {
    /// The paper's Algorithm 1.
    Sampling,
    /// Full SVDD (baseline).
    Full,
    /// Distributed sampling (paper section III-1).
    Distributed,
    /// Luo et al. decomposition/combination baseline.
    Luo,
    /// Kim et al. k-means baseline.
    Kim,
    /// Streaming snapshot: feed the data through
    /// [`crate::sampling::StreamingSvdd`] window by window and take the
    /// final master-set model.
    Streaming,
    /// Online learning: per-point exact add/remove updates through
    /// [`crate::incremental::IncrementalSvdd`] (sliding active set,
    /// staleness-budgeted resyncs).
    Incremental,
    /// Boundary-preserving sample reduction
    /// ([`crate::incremental::reduction`]): keep the rows nearest a
    /// pilot model's decision boundary, then solve on the kept set.
    Reduction,
}

impl Method {
    /// Every method, in the order `fastsvdd train --method` documents
    /// them. Exhaustive by construction: adding a variant without
    /// extending this list breaks the parse↔name round-trip test.
    pub const ALL: [Method; 8] = [
        Method::Sampling,
        Method::Full,
        Method::Distributed,
        Method::Luo,
        Method::Kim,
        Method::Streaming,
        Method::Incremental,
        Method::Reduction,
    ];

    pub fn parse(s: &str) -> Result<Method> {
        Ok(match s {
            "sampling" => Method::Sampling,
            "full" => Method::Full,
            "distributed" => Method::Distributed,
            "luo" => Method::Luo,
            "kim" => Method::Kim,
            "streaming" => Method::Streaming,
            "incremental" => Method::Incremental,
            "reduction" => Method::Reduction,
            other => return Err(Error::Config(format!("unknown method '{other}'"))),
        })
    }

    /// The canonical config/CLI spelling ([`Method::parse`]'s inverse).
    pub fn name(&self) -> &'static str {
        match self {
            Method::Sampling => "sampling",
            Method::Full => "full",
            Method::Distributed => "distributed",
            Method::Luo => "luo",
            Method::Kim => "kim",
            Method::Streaming => "streaming",
            Method::Incremental => "incremental",
            Method::Reduction => "reduction",
        }
    }
}

impl fmt::Display for Method {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(self.name())
    }
}

/// Complete run configuration with defaults.
#[derive(Clone, Debug)]
pub struct RunConfig {
    /// Data set name (see [`crate::data::SHAPE_NAMES`] plus "shuttle",
    /// "tennessee") or a CSV path.
    pub dataset: String,
    pub rows: usize,
    pub bandwidth: f64,
    /// Hands-off kernel bandwidth: when set, the launcher resolves
    /// `bandwidth` from the training data with the closed-form
    /// mean/median criterion ([`crate::svdd::bandwidth`]) before
    /// training. CLI spelling: `--bandwidth auto:mean|auto:median`
    /// (a plain number sets `bandwidth` directly).
    pub bandwidth_auto: Option<AutoBandwidth>,
    pub outlier_fraction: f64,
    pub method: Method,
    pub sample_size: usize,
    pub max_iter: usize,
    pub eps: f64,
    pub consecutive: usize,
    /// Candidate samples solved concurrently per iteration (K >= 1;
    /// 1 = the paper's sequential Algorithm 1).
    pub candidates_per_iter: usize,
    /// Carry each union solve's dual solution into the next iteration
    /// (warm-started SMO; off = the historical cold-init trajectory).
    pub warm_alpha: bool,
    /// SMO working-set selection: "second" (default), "first", or
    /// "legacy" (the pre-Solver loop, byte-for-byte reproducible).
    pub wss: Wss,
    /// SMO active-set shrinking (ignored in legacy mode).
    pub shrinking: bool,
    pub workers: usize,
    /// Seeded pre-shuffle of the row order before distributed sharding
    /// (`None` = shard rows as given; set for ordered/sorted datasets).
    pub shuffle_seed: Option<u64>,
    /// Distributed SV-set combine: `"flat"` (one union solve, the
    /// paper's scheme and the default) or `"tree"`/`"tree:N"`
    /// (hierarchical solves with fanout N).
    pub combine: CombineMode,
    /// Distributed: extra attempts a failed shard is granted before the
    /// run fails (0 = fail on the first error).
    pub max_retries: usize,
    /// Distributed: per-attempt socket deadline in milliseconds
    /// (connect/read/write and heartbeat probes).
    pub worker_timeout_ms: u64,
    /// Distributed: when fewer than this many TCP workers remain alive
    /// (but at least one), remaining shards train locally in the
    /// controller instead of failing the run.
    pub min_workers: usize,
    /// Distributed: stream a CSV dataset to workers in chunks of this
    /// many rows instead of materialising it in the controller
    /// (0 = off, read the whole file).
    pub stream_chunk: usize,
    /// Worker threads for the shared parallel pool (`"auto"` or N).
    pub threads: ThreadCount,
    pub seed: u64,
    /// Kernel-microkernel ISA arm (`auto` resolves `FASTSVDD_ISA` then
    /// hardware detection; the launcher installs it process-wide via
    /// [`crate::linalg::isa::install`]). `avx2`/`neon` are bit-identical
    /// to `scalar`; `fma` relaxes bit-identity and is never picked by
    /// `auto`.
    pub isa: crate::linalg::Isa,
    /// Scoring precision: `"f64"` (reference) or `"f32"` (opt-in panel
    /// path — the XLA boundary's precision as a native engine;
    /// tolerance-only contract, see [`crate::svdd::ModelF32`]).
    pub precision: String,
    /// "native" | "xla" (scoring engine).
    pub scorer: String,
    pub artifact_dir: String,
    /// `serve`: enable the `POST /score` HTTP/JSON ingress.
    pub http: bool,
    /// `serve`: micro-batching linger window in microseconds (the
    /// adaptive window's ceiling).
    pub batch_window_us: u64,
    /// `serve`: cap on rows in flight to the batcher before the edge
    /// sheds new requests.
    pub max_inflight: usize,
    /// `serve`: concurrent-connection cap on the edge.
    pub max_conns: usize,
    /// Online learning: full re-solve (resync) after this many
    /// incremental add/remove updates (0 = only on divergence).
    pub stale_budget: usize,
    /// Online learning: duality gap above which an exhausted
    /// migration loop counts as diverged and forces a resync.
    pub divergence: f64,
    /// `method=reduction`: rows to keep (0 = auto: `max(50, n/10)`).
    pub reduction_target: usize,
    /// Streaming: drive the sliding window with per-point incremental
    /// updates instead of window-snapshot retrains (opt-in; off keeps
    /// the historical snapshot trajectories byte-identical).
    pub stream_incremental: bool,
}

impl Default for RunConfig {
    fn default() -> Self {
        RunConfig {
            dataset: "banana".into(),
            rows: 11_016,
            bandwidth: 0.35,
            bandwidth_auto: None,
            outlier_fraction: 0.001,
            method: Method::Sampling,
            sample_size: 6,
            max_iter: 1000,
            eps: 1e-3,
            consecutive: 5,
            candidates_per_iter: 1,
            warm_alpha: false,
            wss: Wss::Second,
            shrinking: true,
            workers: 4,
            shuffle_seed: None,
            combine: CombineMode::Flat,
            max_retries: 2,
            worker_timeout_ms: 30_000,
            min_workers: 1,
            stream_chunk: 0,
            threads: ThreadCount::Auto,
            seed: 7,
            isa: crate::linalg::Isa::Auto,
            precision: "f64".into(),
            scorer: "native".into(),
            artifact_dir: "artifacts".into(),
            http: false,
            batch_window_us: 2_000,
            max_inflight: 1 << 16,
            max_conns: 1024,
            stale_budget: 64,
            divergence: 1e-3,
            reduction_target: 0,
            stream_incremental: false,
        }
    }
}

impl RunConfig {
    pub fn params(&self) -> SvddParams {
        let mut params = SvddParams {
            kernel: Kernel::gaussian(self.bandwidth),
            outlier_fraction: self.outlier_fraction,
            ..Default::default()
        };
        params.smo.wss = self.wss;
        params.smo.shrinking = self.shrinking;
        params
    }

    pub fn sampling(&self) -> SamplingConfig {
        SamplingConfig {
            sample_size: self.sample_size,
            max_iter: self.max_iter,
            eps_center: self.eps,
            eps_r2: self.eps,
            consecutive: self.consecutive,
            candidates_per_iter: self.candidates_per_iter,
            warm_alpha: self.warm_alpha,
            record_trace: false,
        }
    }

    /// Online-learning knobs this run describes (the trainer's
    /// active-set bound keeps its subsystem default).
    pub fn incremental(&self) -> IncrementalConfig {
        IncrementalConfig {
            stale_budget: self.stale_budget,
            divergence_tol: self.divergence,
            ..Default::default()
        }
    }

    /// Reduction knobs this run describes.
    pub fn reduction(&self) -> ReductionConfig {
        ReductionConfig { target: self.reduction_target, ..Default::default() }
    }

    /// The pool configuration the launcher installs process-wide.
    pub fn parallelism(&self) -> ParallelismConfig {
        ParallelismConfig { threads: self.threads }
    }

    /// The distributed-controller configuration this run describes.
    pub fn distributed(&self) -> DistributedConfig {
        DistributedConfig {
            workers: self.workers,
            sampling: self.sampling(),
            seed: self.seed,
            shuffle_seed: self.shuffle_seed,
            max_retries: self.max_retries,
            worker_timeout: std::time::Duration::from_millis(self.worker_timeout_ms),
            min_workers: self.min_workers,
            combine: self.combine,
        }
    }

    /// Load from a JSON file; unknown keys are rejected (typo guard).
    pub fn load(path: &Path) -> Result<RunConfig> {
        let text = std::fs::read_to_string(path)?;
        Self::from_json_text(&text)
    }

    /// Load the file named by `--config` (defaults when absent) and
    /// apply the CLI overrides on top — the shared front half of
    /// `cmd_train`, `cmd_score` and `cmd_grid`. Options a command does
    /// not accept are simply never present in its `args`.
    pub fn from_args(args: &Args) -> Result<RunConfig> {
        let mut cfg = match args.get("config") {
            Some(path) => RunConfig::load(Path::new(path))?,
            None => RunConfig::default(),
        };
        if let Some(v) = args.get("data") {
            cfg.dataset = v.to_string();
        }
        if let Some(v) = args.get("method") {
            cfg.method = Method::parse(v)?;
        }
        cfg.rows = args.get_usize("rows", cfg.rows)?;
        cfg.bandwidth = args.get_f64("bw", cfg.bandwidth)?;
        if let Some(v) = args.get("bandwidth") {
            if let Some(crit) = v.strip_prefix("auto:") {
                cfg.bandwidth_auto = Some(AutoBandwidth::parse(crit)?);
            } else {
                cfg.bandwidth_auto = None;
                cfg.bandwidth = v.parse::<f64>().map_err(|_| {
                    Error::Config(format!(
                        "--bandwidth expects a number or auto:mean|auto:median, got '{v}'"
                    ))
                })?;
            }
        }
        cfg.outlier_fraction = args.get_f64("f", cfg.outlier_fraction)?;
        cfg.sample_size = args.get_usize("sample-size", cfg.sample_size)?;
        cfg.max_iter = args.get_usize("max-iter", cfg.max_iter)?;
        cfg.candidates_per_iter = args.get_usize("candidates", cfg.candidates_per_iter)?;
        cfg.workers = args.get_usize("workers", cfg.workers)?;
        if args.get("shuffle-seed").is_some() {
            cfg.shuffle_seed = Some(args.get_u64("shuffle-seed", 0)?);
        }
        if let Some(v) = args.get("combine") {
            cfg.combine = CombineMode::parse(v)?;
        }
        cfg.max_retries = args.get_usize("max-retries", cfg.max_retries)?;
        cfg.worker_timeout_ms = args.get_u64("worker-timeout-ms", cfg.worker_timeout_ms)?;
        cfg.min_workers = args.get_usize("min-workers", cfg.min_workers)?;
        cfg.stream_chunk = args.get_usize("stream-chunk", cfg.stream_chunk)?;
        if let Some(v) = args.get("threads") {
            cfg.threads = ThreadCount::parse(v)?;
        }
        cfg.seed = args.get_u64("seed", cfg.seed)?;
        if let Some(v) = args.get("isa") {
            cfg.isa = crate::linalg::Isa::parse(v)?;
        }
        if let Some(v) = args.get("precision") {
            cfg.precision = v.to_string();
        }
        if args.flag("warm-alpha") {
            cfg.warm_alpha = true;
        }
        if let Some(v) = args.get("wss") {
            cfg.wss = Wss::parse(v)?;
        }
        if args.flag("no-shrinking") {
            cfg.shrinking = false;
        }
        if args.flag("xla") {
            cfg.scorer = "xla".into();
        }
        if let Some(v) = args.get("artifacts") {
            cfg.artifact_dir = v.to_string();
        }
        if args.flag("http") {
            cfg.http = true;
        }
        cfg.batch_window_us = args.get_u64("batch-window-us", cfg.batch_window_us)?;
        cfg.max_inflight = args.get_usize("max-inflight", cfg.max_inflight)?;
        cfg.max_conns = args.get_usize("max-conns", cfg.max_conns)?;
        cfg.stale_budget = args.get_usize("stale-budget", cfg.stale_budget)?;
        cfg.divergence = args.get_f64("divergence", cfg.divergence)?;
        cfg.reduction_target = args.get_usize("reduction-target", cfg.reduction_target)?;
        if args.flag("stream-incremental") {
            cfg.stream_incremental = true;
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn from_json_text(text: &str) -> Result<RunConfig> {
        let v = Json::parse(text)?;
        let obj = match &v {
            Json::Obj(m) => m,
            _ => return Err(Error::Config("config root must be an object".into())),
        };
        let mut cfg = RunConfig::default();
        for (key, val) in obj {
            match key.as_str() {
                "dataset" => cfg.dataset = req_str(val, key)?,
                "rows" => cfg.rows = req_num(val, key)? as usize,
                "bandwidth" => cfg.bandwidth = req_num(val, key)?,
                "outlier_fraction" => cfg.outlier_fraction = req_num(val, key)?,
                "method" => cfg.method = Method::parse(&req_str(val, key)?)?,
                "sample_size" => cfg.sample_size = req_num(val, key)? as usize,
                "max_iter" => cfg.max_iter = req_num(val, key)? as usize,
                "eps" => cfg.eps = req_num(val, key)?,
                "consecutive" => cfg.consecutive = req_num(val, key)? as usize,
                "candidates_per_iter" => {
                    cfg.candidates_per_iter = req_num(val, key)? as usize
                }
                "warm_alpha" => cfg.warm_alpha = req_bool(val, key)?,
                "wss" => cfg.wss = Wss::parse(&req_str(val, key)?)?,
                "shrinking" => cfg.shrinking = req_bool(val, key)?,
                "workers" => cfg.workers = req_num(val, key)? as usize,
                "combine" => cfg.combine = CombineMode::parse(&req_str(val, key)?)?,
                "max_retries" => cfg.max_retries = req_num(val, key)? as usize,
                "worker_timeout_ms" => cfg.worker_timeout_ms = req_num(val, key)? as u64,
                "min_workers" => cfg.min_workers = req_num(val, key)? as usize,
                "stream_chunk" => cfg.stream_chunk = req_num(val, key)? as usize,
                "shuffle_seed" => {
                    cfg.shuffle_seed = match val {
                        Json::Null => None,
                        _ => Some(req_num(val, key)? as u64),
                    }
                }
                "threads" => {
                    cfg.threads = match val.as_str() {
                        Some(s) => ThreadCount::parse(s)?,
                        None => ThreadCount::Fixed(req_num(val, key)? as usize),
                    }
                }
                "seed" => cfg.seed = req_num(val, key)? as u64,
                "isa" => cfg.isa = crate::linalg::Isa::parse(&req_str(val, key)?)?,
                "precision" => cfg.precision = req_str(val, key)?,
                "scorer" => cfg.scorer = req_str(val, key)?,
                "artifact_dir" => cfg.artifact_dir = req_str(val, key)?,
                "http" => cfg.http = req_bool(val, key)?,
                "batch_window_us" => cfg.batch_window_us = req_num(val, key)? as u64,
                "max_inflight" => cfg.max_inflight = req_num(val, key)? as usize,
                "max_conns" => cfg.max_conns = req_num(val, key)? as usize,
                "bandwidth_auto" => {
                    cfg.bandwidth_auto = match val {
                        Json::Null => None,
                        _ => Some(AutoBandwidth::parse(&req_str(val, key)?)?),
                    }
                }
                "stale_budget" => cfg.stale_budget = req_num(val, key)? as usize,
                "divergence" => cfg.divergence = req_num(val, key)?,
                "reduction_target" => cfg.reduction_target = req_num(val, key)? as usize,
                "stream_incremental" => cfg.stream_incremental = req_bool(val, key)?,
                other => {
                    return Err(Error::Config(format!("unknown config key '{other}'")))
                }
            }
        }
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn validate(&self) -> Result<()> {
        if self.bandwidth <= 0.0 {
            return Err(Error::Config("bandwidth must be > 0".into()));
        }
        if !(0.0..=1.0).contains(&self.outlier_fraction) || self.outlier_fraction == 0.0 {
            return Err(Error::Config("outlier_fraction must be in (0, 1]".into()));
        }
        if self.rows == 0 {
            return Err(Error::Config("rows must be > 0".into()));
        }
        if self.sample_size < 2 {
            return Err(Error::Config("sample_size must be >= 2".into()));
        }
        if self.candidates_per_iter == 0 {
            return Err(Error::Config("candidates_per_iter must be >= 1".into()));
        }
        if self.threads == ThreadCount::Fixed(0) {
            return Err(Error::Config("threads must be 'auto' or >= 1".into()));
        }
        if self.warm_alpha && self.wss == Wss::Legacy {
            // fail here instead of mid-training: the legacy solver
            // rejects the warm starts every union solve would pass it
            return Err(Error::Config(
                "warm_alpha cannot be combined with wss=legacy (the legacy \
                 solver exists to replay cold-start trajectories)"
                    .into(),
            ));
        }
        if !matches!(self.scorer.as_str(), "native" | "xla") {
            return Err(Error::Config(format!("unknown scorer '{}'", self.scorer)));
        }
        if !matches!(self.precision.as_str(), "f64" | "f32") {
            return Err(Error::Config(format!(
                "unknown precision '{}' (expected f64|f32)",
                self.precision
            )));
        }
        if self.worker_timeout_ms == 0 {
            return Err(Error::Config("worker_timeout_ms must be >= 1".into()));
        }
        if self.min_workers == 0 {
            return Err(Error::Config("min_workers must be >= 1".into()));
        }
        if self.batch_window_us == 0 {
            return Err(Error::Config("batch_window_us must be >= 1".into()));
        }
        if self.max_inflight == 0 {
            return Err(Error::Config("max_inflight must be >= 1".into()));
        }
        if self.max_conns == 0 {
            return Err(Error::Config("max_conns must be >= 1".into()));
        }
        if self.divergence <= 0.0 {
            return Err(Error::Config("divergence must be > 0".into()));
        }
        Ok(())
    }
}

fn req_str(v: &Json, key: &str) -> Result<String> {
    v.as_str()
        .map(|s| s.to_string())
        .ok_or_else(|| Error::Config(format!("'{key}' must be a string")))
}

fn req_num(v: &Json, key: &str) -> Result<f64> {
    v.as_f64()
        .ok_or_else(|| Error::Config(format!("'{key}' must be a number")))
}

fn req_bool(v: &Json, key: &str) -> Result<bool> {
    v.as_bool()
        .ok_or_else(|| Error::Config(format!("'{key}' must be a boolean")))
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_valid() {
        RunConfig::default().validate().unwrap();
    }

    #[test]
    fn parses_full_config() {
        let cfg = RunConfig::from_json_text(
            r#"{"dataset": "two-donut", "rows": 50000, "bandwidth": 0.4,
                "method": "distributed", "workers": 8, "sample_size": 11,
                "scorer": "xla", "seed": 42}"#,
        )
        .unwrap();
        assert_eq!(cfg.dataset, "two-donut");
        assert_eq!(cfg.method, Method::Distributed);
        assert_eq!(cfg.workers, 8);
        assert_eq!(cfg.seed, 42);
        // untouched keys keep defaults
        assert_eq!(cfg.max_iter, 1000);
    }

    #[test]
    fn unknown_key_rejected() {
        assert!(RunConfig::from_json_text(r#"{"bananana": 1}"#).is_err());
    }

    #[test]
    fn bad_values_rejected() {
        assert!(RunConfig::from_json_text(r#"{"bandwidth": -1}"#).is_err());
        assert!(RunConfig::from_json_text(r#"{"outlier_fraction": 2}"#).is_err());
        assert!(RunConfig::from_json_text(r#"{"sample_size": 1}"#).is_err());
        assert!(RunConfig::from_json_text(r#"{"scorer": "gpu"}"#).is_err());
        assert!(RunConfig::from_json_text(r#"{"method": "magic"}"#).is_err());
        assert!(RunConfig::from_json_text(r#"{"candidates_per_iter": 0}"#).is_err());
        assert!(RunConfig::from_json_text(r#"{"threads": 0}"#).is_err());
        assert!(RunConfig::from_json_text(r#"{"threads": "lots"}"#).is_err());
    }

    #[test]
    fn shuffle_seed_parses_and_defaults_off() {
        assert_eq!(RunConfig::default().shuffle_seed, None);
        let cfg = RunConfig::from_json_text(r#"{"shuffle_seed": 99}"#).unwrap();
        assert_eq!(cfg.shuffle_seed, Some(99));
        let cfg = RunConfig::from_json_text(r#"{"shuffle_seed": null}"#).unwrap();
        assert_eq!(cfg.shuffle_seed, None);
    }

    #[test]
    fn solver_keys_parse_and_flow() {
        let cfg =
            RunConfig::from_json_text(r#"{"wss": "legacy", "shrinking": false}"#).unwrap();
        assert_eq!(cfg.wss, Wss::Legacy);
        assert!(!cfg.shrinking);
        let p = cfg.params();
        assert_eq!(p.smo.wss, Wss::Legacy);
        assert!(!p.smo.shrinking);
        let warm = RunConfig::from_json_text(r#"{"warm_alpha": true}"#).unwrap();
        assert!(warm.warm_alpha);
        assert!(warm.sampling().warm_alpha);
        // defaults: fast path on, warm carry off
        let d = RunConfig::default();
        assert!(!d.warm_alpha);
        assert_eq!(d.wss, Wss::Second);
        assert!(d.shrinking);
        // bad values rejected
        assert!(RunConfig::from_json_text(r#"{"wss": "zeroth"}"#).is_err());
        assert!(RunConfig::from_json_text(r#"{"warm_alpha": 3}"#).is_err());
        assert!(RunConfig::from_json_text(r#"{"shrinking": "yes"}"#).is_err());
        // legacy mode replays cold starts; warm carry contradicts it
        assert!(
            RunConfig::from_json_text(r#"{"warm_alpha": true, "wss": "legacy"}"#).is_err()
        );
    }

    #[test]
    fn threads_and_candidates_parse() {
        let cfg = RunConfig::from_json_text(
            r#"{"threads": "auto", "candidates_per_iter": 4}"#,
        )
        .unwrap();
        assert_eq!(cfg.threads, ThreadCount::Auto);
        assert_eq!(cfg.candidates_per_iter, 4);
        assert_eq!(cfg.sampling().candidates_per_iter, 4);
        let cfg = RunConfig::from_json_text(r#"{"threads": 8}"#).unwrap();
        assert_eq!(cfg.threads, ThreadCount::Fixed(8));
        assert_eq!(cfg.parallelism().threads, ThreadCount::Fixed(8));
    }

    #[test]
    fn method_parse_all() {
        for (s, m) in [
            ("sampling", Method::Sampling),
            ("full", Method::Full),
            ("distributed", Method::Distributed),
            ("luo", Method::Luo),
            ("kim", Method::Kim),
            ("streaming", Method::Streaming),
            ("incremental", Method::Incremental),
            ("reduction", Method::Reduction),
        ] {
            assert_eq!(Method::parse(s).unwrap(), m);
        }
        assert!(Method::parse("magic").is_err());
    }

    #[test]
    fn method_name_parse_roundtrip_exhaustive() {
        // exhaustiveness: Method::ALL and Method::name() both match on
        // every variant, so a new variant that misses either fails to
        // compile or fails here
        let mut seen = Vec::new();
        for m in Method::ALL {
            assert_eq!(Method::parse(m.name()).unwrap(), m, "parse != name for {m:?}");
            assert_eq!(m.to_string(), m.name(), "Display != name for {m:?}");
            assert!(!seen.contains(&m.name()), "duplicate name '{}'", m.name());
            seen.push(m.name());
        }
        assert_eq!(seen.len(), Method::ALL.len());
    }

    #[test]
    fn from_args_applies_overrides_on_defaults() {
        let argv: Vec<String> = [
            "train", "--data", "star", "--method", "streaming", "--rows", "500",
            "--bw", "0.2", "--seed", "99", "--threads", "2", "--xla",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let cfg = RunConfig::from_args(&Args::parse(&argv).unwrap()).unwrap();
        assert_eq!(cfg.dataset, "star");
        assert_eq!(cfg.method, Method::Streaming);
        assert_eq!(cfg.rows, 500);
        assert_eq!(cfg.bandwidth, 0.2);
        assert_eq!(cfg.seed, 99);
        assert_eq!(cfg.threads, ThreadCount::Fixed(2));
        assert_eq!(cfg.scorer, "xla");
        // untouched keys keep defaults
        assert_eq!(cfg.sample_size, 6);
        // overrides are validated like file values
        let bad: Vec<String> = ["train", "--bw", "-1"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert!(RunConfig::from_args(&Args::parse(&bad).unwrap()).is_err());
    }

    #[test]
    fn serving_keys_parse_and_flow() {
        // defaults: HTTP ingress off, 2ms window, 64k rows, 1k conns
        let d = RunConfig::default();
        assert!(!d.http);
        assert_eq!(d.batch_window_us, 2_000);
        assert_eq!(d.max_inflight, 1 << 16);
        assert_eq!(d.max_conns, 1024);
        // JSON spellings round-trip
        let cfg = RunConfig::from_json_text(
            r#"{"http": true, "batch_window_us": 500,
                "max_inflight": 4096, "max_conns": 64}"#,
        )
        .unwrap();
        assert!(cfg.http);
        assert_eq!(cfg.batch_window_us, 500);
        assert_eq!(cfg.max_inflight, 4096);
        assert_eq!(cfg.max_conns, 64);
        // CLI spellings override on top
        let argv: Vec<String> = [
            "serve", "--http", "--batch-window-us", "750", "--max-inflight",
            "128", "--max-conns", "9",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let cfg = RunConfig::from_args(&Args::parse(&argv).unwrap()).unwrap();
        assert!(cfg.http);
        assert_eq!(cfg.batch_window_us, 750);
        assert_eq!(cfg.max_inflight, 128);
        assert_eq!(cfg.max_conns, 9);
        // degenerate values rejected, file or CLI alike
        assert!(RunConfig::from_json_text(r#"{"batch_window_us": 0}"#).is_err());
        assert!(RunConfig::from_json_text(r#"{"max_inflight": 0}"#).is_err());
        assert!(RunConfig::from_json_text(r#"{"max_conns": 0}"#).is_err());
        assert!(RunConfig::from_json_text(r#"{"http": "yes"}"#).is_err());
        let bad: Vec<String> = ["serve", "--max-conns", "0"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert!(RunConfig::from_args(&Args::parse(&bad).unwrap()).is_err());
    }

    #[test]
    fn isa_and_precision_keys_parse_and_flow() {
        use crate::linalg::Isa;
        // defaults: auto dispatch, f64 reference precision
        let d = RunConfig::default();
        assert_eq!(d.isa, Isa::Auto);
        assert_eq!(d.precision, "f64");
        // JSON spellings
        let cfg =
            RunConfig::from_json_text(r#"{"isa": "scalar", "precision": "f32"}"#).unwrap();
        assert_eq!(cfg.isa, Isa::Scalar);
        assert_eq!(cfg.precision, "f32");
        // CLI spellings override on top
        let argv: Vec<String> = ["score", "--isa", "fma", "--precision", "f32"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let cfg = RunConfig::from_args(&Args::parse(&argv).unwrap()).unwrap();
        assert_eq!(cfg.isa, Isa::Fma);
        assert_eq!(cfg.precision, "f32");
        // bad spellings rejected at parse/validate time (arm
        // *availability* is checked at install, not here — a config
        // written on an x86 box must still parse on an arm box)
        assert!(RunConfig::from_json_text(r#"{"isa": "sse9"}"#).is_err());
        assert!(RunConfig::from_json_text(r#"{"precision": "f16"}"#).is_err());
        let bad: Vec<String> = ["score", "--precision", "f128"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert!(RunConfig::from_args(&Args::parse(&bad).unwrap()).is_err());
    }

    #[test]
    fn fault_tolerance_keys_parse_and_flow() {
        // defaults: flat combine, 2 retries, 30s deadline, no
        // degradation threshold, streaming off
        let d = RunConfig::default();
        assert_eq!(d.combine, CombineMode::Flat);
        assert_eq!(d.max_retries, 2);
        assert_eq!(d.worker_timeout_ms, 30_000);
        assert_eq!(d.min_workers, 1);
        assert_eq!(d.stream_chunk, 0);
        // JSON spellings flow into the controller config
        let cfg = RunConfig::from_json_text(
            r#"{"combine": "tree:8", "max_retries": 5, "worker_timeout_ms": 1000,
                "min_workers": 2, "stream_chunk": 256}"#,
        )
        .unwrap();
        assert_eq!(cfg.combine, CombineMode::Tree { fanout: 8 });
        assert_eq!(cfg.stream_chunk, 256);
        let dcfg = cfg.distributed();
        assert_eq!(dcfg.max_retries, 5);
        assert_eq!(dcfg.worker_timeout, std::time::Duration::from_millis(1000));
        assert_eq!(dcfg.min_workers, 2);
        assert_eq!(dcfg.combine, CombineMode::Tree { fanout: 8 });
        // CLI spellings override on top
        let argv: Vec<String> = [
            "train", "--combine", "tree", "--max-retries", "0", "--worker-timeout-ms",
            "500", "--min-workers", "3", "--stream-chunk", "64",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let cfg = RunConfig::from_args(&Args::parse(&argv).unwrap()).unwrap();
        assert_eq!(cfg.combine, CombineMode::Tree { fanout: 4 });
        assert_eq!(cfg.max_retries, 0);
        assert_eq!(cfg.worker_timeout_ms, 500);
        assert_eq!(cfg.min_workers, 3);
        assert_eq!(cfg.stream_chunk, 64);
        // degenerate values rejected, file or CLI alike
        assert!(RunConfig::from_json_text(r#"{"combine": "ring"}"#).is_err());
        assert!(RunConfig::from_json_text(r#"{"combine": "tree:1"}"#).is_err());
        assert!(RunConfig::from_json_text(r#"{"worker_timeout_ms": 0}"#).is_err());
        assert!(RunConfig::from_json_text(r#"{"min_workers": 0}"#).is_err());
        let bad: Vec<String> = ["train", "--min-workers", "0"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert!(RunConfig::from_args(&Args::parse(&bad).unwrap()).is_err());
    }

    #[test]
    fn online_learning_keys_parse_and_flow() {
        // defaults: 64-update budget, 1e-3 divergence, auto reduction
        // target, snapshot streaming, fixed bandwidth
        let d = RunConfig::default();
        assert_eq!(d.stale_budget, 64);
        assert_eq!(d.divergence, 1e-3);
        assert_eq!(d.reduction_target, 0);
        assert!(!d.stream_incremental);
        assert_eq!(d.bandwidth_auto, None);
        // JSON spellings flow into the subsystem configs
        let cfg = RunConfig::from_json_text(
            r#"{"method": "incremental", "stale_budget": 16, "divergence": 0.01,
                "reduction_target": 200, "stream_incremental": true,
                "bandwidth_auto": "median"}"#,
        )
        .unwrap();
        assert_eq!(cfg.method, Method::Incremental);
        assert_eq!(cfg.bandwidth_auto, Some(AutoBandwidth::Median));
        let icfg = cfg.incremental();
        assert_eq!(icfg.stale_budget, 16);
        assert_eq!(icfg.divergence_tol, 0.01);
        assert_eq!(cfg.reduction().target, 200);
        assert!(cfg.stream_incremental);
        // "off"/null both mean fixed bandwidth
        let cfg = RunConfig::from_json_text(r#"{"bandwidth_auto": null}"#).unwrap();
        assert_eq!(cfg.bandwidth_auto, None);
        // CLI spellings override on top; --bandwidth does double duty
        let argv: Vec<String> = [
            "train", "--method", "reduction", "--stale-budget", "8",
            "--divergence", "0.5", "--reduction-target", "99",
            "--stream-incremental", "--bandwidth", "auto:mean",
        ]
        .iter()
        .map(|s| s.to_string())
        .collect();
        let cfg = RunConfig::from_args(&Args::parse(&argv).unwrap()).unwrap();
        assert_eq!(cfg.method, Method::Reduction);
        assert_eq!(cfg.stale_budget, 8);
        assert_eq!(cfg.divergence, 0.5);
        assert_eq!(cfg.reduction_target, 99);
        assert!(cfg.stream_incremental);
        assert_eq!(cfg.bandwidth_auto, Some(AutoBandwidth::Mean));
        // a numeric --bandwidth sets sigma and clears the auto mode
        let argv: Vec<String> = ["train", "--bandwidth", "0.7"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        let cfg = RunConfig::from_args(&Args::parse(&argv).unwrap()).unwrap();
        assert_eq!(cfg.bandwidth, 0.7);
        assert_eq!(cfg.bandwidth_auto, None);
        // degenerate values rejected, file or CLI alike
        assert!(RunConfig::from_json_text(r#"{"divergence": 0}"#).is_err());
        assert!(RunConfig::from_json_text(r#"{"bandwidth_auto": "magic"}"#).is_err());
        let bad: Vec<String> = ["train", "--bandwidth", "auto:mode"]
            .iter()
            .map(|s| s.to_string())
            .collect();
        assert!(RunConfig::from_args(&Args::parse(&bad).unwrap()).is_err());
    }

    #[test]
    fn params_roundtrip() {
        let cfg = RunConfig::default();
        let p = cfg.params();
        assert_eq!(p.kernel.bw(), Some(0.35));
        let s = cfg.sampling();
        assert_eq!(s.sample_size, 6);
    }
}
