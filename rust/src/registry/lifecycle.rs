//! The lifecycle driver: drift → warm-start retrain → publish →
//! promote → hot-swap.
//!
//! [`Lifecycle`] closes the loop the paper's conclusion asks for
//! ("fast periodic training using large data sets"): a
//! [`StreamingSvdd`](crate::sampling::StreamingSvdd) watches the
//! production stream and reports
//! [`DriftStatus::Drifted`](crate::sampling::DriftStatus); the driver
//! then retrains on the recent window —
//! [`SamplingTrainer::train_warm`](crate::sampling::SamplingTrainer::train_warm),
//! seeded from the current champion's SV set, so the run converges in
//! far fewer iterations than a cold start — publishes the result to the
//! versioned [`Registry`], promotes it, and swaps it into the serving
//! [`ModelSlot`] without dropping a connection.
//!
//! The driver is deliberately synchronous and single-owner (one
//! lifecycle per registry, matching the store's single-writer rule);
//! serving stays concurrent because the slot swap is a pointer
//! replacement.
//!
//! Retraining goes through the unified [`crate::engine`]: the driver
//! holds a `Box<dyn Trainer>` (the sampling method by default, any
//! registered trainer via [`Lifecycle::with_trainer`]) and passes the
//! champion as [`TrainContext::warm_start`], so the warm/cold decision
//! and the telemetry path are the same code every other consumer uses.
//!
//! With [`Lifecycle::with_online`] the drift response gets a cheaper
//! first line: drifted windows slide point-by-point through an exact
//! [`IncrementalSvdd`] and the refreshed model is promoted directly
//! ([`Lifecycle::respond`]); a full retrain runs only when the
//! staleness budget is spent or the state machine diverges — the
//! "retrain continuously" loop without paying a solver cold start per
//! drift event.

use std::sync::Arc;

use crate::config::Method;
use crate::engine::{self, TrainContext, Trainer};
use crate::error::{Error, Result};
use crate::incremental::{IncrementalConfig, IncrementalSvdd, InsertionOrder};
use crate::metrics::Metrics;
use crate::registry::store::Registry;
use crate::registry::version::{VersionId, VersionMeta};
use crate::sampling::{DriftStatus, SamplingConfig};
use crate::scoring::batcher::ModelSlot;
use crate::svdd::model::SvddModel;
use crate::svdd::trainer::SvddParams;
use crate::util::matrix::Matrix;
use crate::util::timer::Stopwatch;

/// What one lifecycle retrain produced.
#[derive(Clone, Debug)]
pub struct LifecycleReport {
    /// Registry id of the (now champion) model.
    pub id: VersionId,
    /// Threshold of the promoted model.
    pub r2: f64,
    /// Algorithm-1 iterations the retrain took.
    pub iterations: usize,
    pub converged: bool,
    /// Whether `SV*` was seeded from the previous champion.
    pub warm_start: bool,
    /// Retrain wall time, seconds.
    pub seconds: f64,
    /// Slot epoch after the swap (None when no slot is attached).
    pub epoch: Option<u64>,
}

/// The incremental drift-response state ([`Lifecycle::with_online`]).
struct OnlineState {
    /// User-facing knobs; `stale_budget` is enforced *here* (a spent
    /// budget means a full retrain + reseed), so the state machine
    /// itself runs with its internal staleness resync disabled.
    cfg: IncrementalConfig,
    inc: Option<IncrementalSvdd>,
    /// FIFO view over the state machine's swap-remove slots.
    order: InsertionOrder,
}

/// Drift-to-swap driver over one registry and (optionally) one serving
/// slot.
pub struct Lifecycle {
    registry: Registry,
    params: SvddParams,
    cfg: SamplingConfig,
    trainer: Box<dyn Trainer>,
    slot: Option<ModelSlot>,
    metrics: Arc<Metrics>,
    online: Option<OnlineState>,
}

impl Lifecycle {
    pub fn new(registry: Registry, params: SvddParams, cfg: SamplingConfig) -> Lifecycle {
        Lifecycle {
            registry,
            params,
            cfg,
            trainer: engine::trainer_for(Method::Sampling),
            slot: None,
            metrics: Arc::new(Metrics::new()),
            online: None,
        }
    }

    /// Route drift responses through the exact incremental path: with
    /// this set, [`Lifecycle::respond`] slides drifted windows through
    /// an [`IncrementalSvdd`] and promotes the refreshed model without
    /// a retrain. `cfg.stale_budget` bounds how many incremental
    /// updates may accumulate before the next drift forces a full
    /// retrain (plus state-machine reseed); 0 means never force one.
    pub fn with_online(mut self, cfg: IncrementalConfig) -> Lifecycle {
        self.online = Some(OnlineState { cfg, inc: None, order: InsertionOrder::new() });
        self
    }

    /// Retrain with a different method: any [`Trainer`] (usually from
    /// [`engine::trainer_for`]). The champion still flows in as
    /// [`TrainContext::warm_start`]; trainers that cannot warm-start
    /// ignore it.
    pub fn with_trainer(mut self, trainer: Box<dyn Trainer>) -> Lifecycle {
        self.trainer = trainer;
        self
    }

    /// Attach the serving slot retrains should swap into (e.g.
    /// [`ScoreServer::slot`](crate::scoring::ScoreServer::slot)).
    pub fn with_slot(mut self, slot: ModelSlot) -> Lifecycle {
        self.slot = Some(slot);
        self
    }

    /// Share a metrics registry (e.g. the serving process's, so swap and
    /// retrain counters land next to the scoring counters).
    pub fn with_metrics(mut self, metrics: Arc<Metrics>) -> Lifecycle {
        self.metrics = metrics;
        self
    }

    pub fn registry(&self) -> &Registry {
        &self.registry
    }

    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Train on `data`, publish, promote and (if a slot is attached)
    /// hot-swap. Warm-starts from the current champion when one exists
    /// and its dimension matches; falls back to a cold start otherwise.
    /// This is both the bootstrap path (empty registry → cold) and the
    /// drift path (champion → warm).
    pub fn retrain(&mut self, data: &Matrix, seed: u64) -> Result<LifecycleReport> {
        // Guard before any training or registry mutation: a window whose
        // dimension cannot be served by the attached slot must not become
        // champion (it would leave the registry pointing at an
        // unservable model and bury the good one in history).
        if let Some(slot) = &self.slot {
            if slot.dim() != data.cols() {
                return Err(Error::invalid(format!(
                    "retrain window is {}-d but the serving slot is {}-d",
                    data.cols(),
                    slot.dim()
                )));
            }
        }
        let mut span = crate::obs::Span::enter("lifecycle.retrain");
        let champion = self.registry.champion_model()?;
        let warm_from = champion
            .as_ref()
            .map(|(_, m)| m)
            .filter(|m| m.dim() == data.cols());

        // solver telemetry lands next to the lifecycle counters (via
        // the context's metrics sink) so a serving process can see what
        // its background retrains cost
        let mut ctx =
            TrainContext::new(self.params, self.cfg, seed).with_metrics(&self.metrics);
        if let Some(init) = warm_from {
            ctx = ctx.with_warm_start(init);
        }
        let report = engine::run(self.trainer.as_ref(), &ctx, data)?;
        let seconds = report.seconds;
        self.metrics.retrain_latency.observe(seconds);
        if report.warm_start {
            self.metrics.retrains_warm.inc();
        } else {
            self.metrics.retrains_cold.inc();
        }

        let meta = VersionMeta::from_report(&report, data);
        let id = self.registry.publish(&report.model, meta)?;
        self.registry.promote(&id)?;
        crate::obs::emit(
            "lifecycle.promote",
            vec![("version", crate::obs::Value::Str(id.to_string()))],
        );
        let epoch = self.swap_into_slot(&report.model)?;
        if span.is_live() {
            span.str("version", id.to_string());
            span.u64("warm", report.warm_start as u64);
            span.f64("r2", report.model.r2());
        }
        drop(span);
        Ok(LifecycleReport {
            id,
            r2: report.model.r2(),
            iterations: report.iterations,
            converged: report.converged,
            warm_start: report.warm_start,
            seconds,
            epoch,
        })
    }

    /// React to a drift verdict: [`DriftStatus::Drifted`] triggers a
    /// [`Lifecycle::retrain`] on `window` (the recent data the monitor
    /// drifted on); anything else is a no-op.
    pub fn observe(
        &mut self,
        status: DriftStatus,
        window: &Matrix,
        seed: u64,
    ) -> Result<Option<LifecycleReport>> {
        let action = match status {
            DriftStatus::Drifted => "retrain",
            DriftStatus::Stable => "none",
            DriftStatus::Suspect => "watch",
        };
        crate::obs::emit(
            "lifecycle.drift",
            vec![("action", crate::obs::Value::Str(action.to_string()))],
        );
        match status {
            DriftStatus::Drifted => self.retrain(window, seed).map(Some),
            DriftStatus::Stable | DriftStatus::Suspect => Ok(None),
        }
    }

    /// React to a drift verdict like [`Lifecycle::observe`], but route
    /// [`DriftStatus::Drifted`] through the incremental path when
    /// [`Lifecycle::with_online`] is configured: the drift window
    /// slides point-by-point through the maintained state machine (the
    /// active set stays one window wide) and the refreshed model is
    /// published, promoted and hot-swapped — no retrain. A full
    /// [`Lifecycle::retrain`] (followed by a state-machine reseed from
    /// the window) runs only when the staleness budget is spent, the
    /// stream dimension changed, or no state machine exists yet.
    /// Without online configuration this is exactly `observe`.
    pub fn respond(
        &mut self,
        status: DriftStatus,
        window: &Matrix,
        seed: u64,
    ) -> Result<Option<LifecycleReport>> {
        if self.online.is_none() {
            return self.observe(status, window, seed);
        }
        if status != DriftStatus::Drifted {
            let action = if status == DriftStatus::Suspect { "watch" } else { "none" };
            crate::obs::emit(
                "lifecycle.drift",
                vec![("action", crate::obs::Value::Str(action.to_string()))],
            );
            return Ok(None);
        }
        let needs_full = {
            let st = self.online.as_ref().expect("checked above");
            match &st.inc {
                None => true,
                Some(inc) => {
                    (st.cfg.stale_budget > 0 && inc.since_resync() >= st.cfg.stale_budget)
                        || inc.dim() != Some(window.cols())
                }
            }
        };
        if needs_full {
            crate::obs::emit(
                "lifecycle.drift",
                vec![("action", crate::obs::Value::Str("retrain".to_string()))],
            );
            let report = self.retrain(window, seed)?;
            // reseed the state machine from the drift window; staleness
            // is budgeted by this driver, so the machine itself only
            // resyncs on divergence
            let icfg = IncrementalConfig {
                stale_budget: 0,
                ..self.online.as_ref().expect("checked above").cfg
            };
            let inc = IncrementalSvdd::with_data(self.params, icfg, window)?;
            self.metrics.incremental_resyncs.inc();
            let st = self.online.as_mut().expect("checked above");
            st.order = InsertionOrder::new();
            for i in 0..window.rows() {
                st.order.record_add(i);
            }
            st.inc = Some(inc);
            return Ok(Some(report));
        }
        crate::obs::emit(
            "lifecycle.drift",
            vec![("action", crate::obs::Value::Str("incremental".to_string()))],
        );
        let mut span = crate::obs::Span::enter("lifecycle.respond");
        let sw = Stopwatch::start();
        let st = self.online.as_mut().expect("checked above");
        let inc = st.inc.as_mut().expect("checked above");
        let before_updates = inc.updates();
        let before_resyncs = inc.resyncs();
        for i in 0..window.rows() {
            inc.add_point(window.row(i))?;
            st.order.record_add(inc.len() - 1);
            let oldest = st.order.oldest().expect("seeded window is non-empty");
            let last = inc.len() - 1;
            inc.remove_point(oldest)?;
            st.order.record_swap_remove(oldest, last);
        }
        let slides = ((inc.updates() - before_updates) / 2) as usize;
        let resyncs = inc.resyncs() - before_resyncs;
        let converged = inc.gap() <= self.params.smo.tol;
        let model = inc.model()?;
        self.metrics.incremental_updates.add(inc.updates() - before_updates);
        self.metrics.incremental_resyncs.add(resyncs);
        self.check_servable(&model)?;
        let mut meta = VersionMeta::new(&model, window);
        meta.iterations = slides;
        meta.converged = converged;
        meta.warm_start = true;
        let id = self.registry.publish(&model, meta)?;
        self.registry.promote(&id)?;
        crate::obs::emit(
            "lifecycle.promote",
            vec![("version", crate::obs::Value::Str(id.to_string()))],
        );
        let epoch = self.swap_into_slot(&model)?;
        if span.is_live() {
            span.str("version", id.to_string());
            span.u64("slides", slides as u64);
            span.f64("r2", model.r2());
        }
        drop(span);
        Ok(Some(LifecycleReport {
            id,
            r2: model.r2(),
            iterations: slides,
            converged,
            warm_start: true,
            seconds: sw.elapsed_secs(),
            epoch,
        }))
    }

    /// Promote an already published version and swap it into the slot.
    /// The model is loaded and checked against the slot *before* the
    /// registry champion moves, so a failure leaves registry and serve
    /// path consistent.
    pub fn promote(&mut self, id: &VersionId) -> Result<()> {
        let model = self.registry.load(id)?;
        self.check_servable(&model)?;
        self.registry.promote(id)?;
        self.swap_into_slot(&model)?;
        Ok(())
    }

    /// Restore the previous champion (registry rollback + slot swap).
    /// Like [`Lifecycle::promote`], the restored model is validated
    /// against the slot before the registry history is popped.
    pub fn rollback(&mut self) -> Result<VersionId> {
        match self.registry.peek_rollback()? {
            Some(prev) => {
                let model = self.registry.load(&prev)?;
                self.check_servable(&model)?;
                let id = self.registry.rollback()?;
                self.swap_into_slot(&model)?;
                Ok(id)
            }
            // empty history: let the store produce its canonical error
            None => self.registry.rollback(),
        }
    }

    /// Prune old versions (champion/history/most-recent `keep` survive).
    pub fn gc(&mut self, keep: usize) -> Result<Vec<VersionId>> {
        self.registry.gc(keep)
    }

    /// Err when a slot is attached and cannot serve `model`.
    fn check_servable(&self, model: &SvddModel) -> Result<()> {
        if let Some(slot) = &self.slot {
            if slot.dim() != model.dim() {
                return Err(Error::invalid(format!(
                    "model is {}-d but the serving slot is {}-d",
                    model.dim(),
                    slot.dim()
                )));
            }
        }
        Ok(())
    }

    fn swap_into_slot(&self, model: &SvddModel) -> Result<Option<u64>> {
        match &self.slot {
            Some(slot) => {
                let epoch = slot.swap(model.clone())?;
                self.metrics.model_swaps.inc();
                crate::obs::emit(
                    "lifecycle.swap",
                    vec![
                        ("version", crate::obs::Value::Str(model.content_id())),
                        ("epoch", crate::obs::Value::U64(epoch)),
                    ],
                );
                Ok(Some(epoch))
            }
            None => Ok(None),
        }
    }
}

/// One poll of `serve --registry --watch`: if the registry's champion
/// differs from `last`, load it, swap it into `slot` and return its id;
/// `None` when the champion is unchanged (or none is promoted yet).
/// Errors (unreadable manifest, dimension mismatch) leave the slot
/// untouched so the server keeps answering on the old model.
pub fn sync_champion(
    registry: &Registry,
    slot: &ModelSlot,
    last: Option<&VersionId>,
) -> Result<Option<VersionId>> {
    // manifest-only check first: the steady state (champion unchanged)
    // must not pay a model-file read + parse + hash on every poll
    let entry = match registry.champion()? {
        Some(e) if last != Some(&e.id) => e,
        _ => return Ok(None),
    };
    let id = entry.id;
    let model = registry.load(&id)?;
    if model.dim() != slot.dim() {
        return Err(Error::Registry(format!(
            "champion {id} is {}-d but the serving slot is {}-d",
            model.dim(),
            slot.dim()
        )));
    }
    slot.swap(model)?;
    Ok(Some(id))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{banana::Banana, Generator};
    use crate::sampling::SamplingTrainer;

    fn temp_registry(tag: &str) -> Registry {
        let dir = std::env::temp_dir().join(format!(
            "fastsvdd_lifecycle_{tag}_{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        Registry::open(&dir).unwrap()
    }

    fn lifecycle(tag: &str) -> Lifecycle {
        let params = SvddParams::gaussian(0.35, 0.001);
        let cfg = SamplingConfig { sample_size: 6, ..Default::default() };
        Lifecycle::new(temp_registry(tag), params, cfg)
    }

    fn shifted(n: usize, seed: u64) -> Matrix {
        let mut m = Banana::default().generate(n, seed);
        for i in 0..m.rows() {
            m.row_mut(i)[0] += 8.0;
        }
        m
    }

    #[test]
    fn first_retrain_is_cold_then_warm() {
        let mut lc = lifecycle("coldwarm");
        let data = Banana::default().generate(4000, 1);
        let first = lc.retrain(&data, 7).unwrap();
        assert!(!first.warm_start, "empty registry must cold-start");
        assert_eq!(lc.registry().champion().unwrap().unwrap().id, first.id);
        assert_eq!(lc.metrics().retrains_cold.get(), 1);
        assert!(lc.metrics().smo_iterations.get() > 0, "solver telemetry missing");
        assert!(lc.metrics().solver_calls.get() > 0);

        let second = lc.retrain(&data, 13).unwrap();
        assert!(second.warm_start, "champion present must warm-start");
        assert!(
            second.iterations < first.iterations,
            "warm {} >= cold {}",
            second.iterations,
            first.iterations
        );
        assert_eq!(lc.metrics().retrains_warm.get(), 1);
        // both versions live; champion moved to the second
        assert_eq!(lc.registry().list().unwrap().len(), 2);
        assert_eq!(lc.registry().champion().unwrap().unwrap().id, second.id);
        let meta = lc.registry().get(&second.id).unwrap().meta;
        assert!(meta.warm_start);
        assert_eq!(meta.iterations, second.iterations);
        std::fs::remove_dir_all(lc.registry().root()).ok();
    }

    #[test]
    fn custom_trainer_retrains_with_another_method() {
        let params = SvddParams::gaussian(0.35, 0.01);
        let cfg = SamplingConfig { sample_size: 6, ..Default::default() };
        let mut lc = Lifecycle::new(temp_registry("fulltrainer"), params, cfg)
            .with_trainer(engine::trainer_for(Method::Full));
        let data = Banana::default().generate(600, 8);
        let first = lc.retrain(&data, 1).unwrap();
        assert!(!first.warm_start);
        assert!(first.converged);
        assert!(lc.metrics().smo_iterations.get() > 0);
        // a champion now exists, but the full trainer ignores warm
        // starts — and the identical deterministic solve republishes
        // the same content-addressed version
        let again = lc.retrain(&data, 2).unwrap();
        assert!(!again.warm_start);
        assert_eq!(again.id, first.id);
        std::fs::remove_dir_all(lc.registry().root()).ok();
    }

    #[test]
    fn observe_acts_only_on_drifted() {
        let mut lc = lifecycle("observe");
        let data = Banana::default().generate(1500, 2);
        assert!(lc.observe(DriftStatus::Stable, &data, 1).unwrap().is_none());
        assert!(lc.observe(DriftStatus::Suspect, &data, 2).unwrap().is_none());
        assert!(lc.registry().list().unwrap().is_empty());
        let rep = lc.observe(DriftStatus::Drifted, &data, 3).unwrap().unwrap();
        assert_eq!(lc.registry().champion().unwrap().unwrap().id, rep.id);
        std::fs::remove_dir_all(lc.registry().root()).ok();
    }

    #[test]
    fn respond_routes_drift_through_incremental_path() {
        let mut lc = lifecycle("online").with_online(IncrementalConfig {
            stale_budget: 10_000, // never trips in this test
            ..Default::default()
        });
        let a = Banana::default().generate(256, 2);
        // first drift: no state machine yet -> full (cold) retrain + reseed
        let first = lc.respond(DriftStatus::Drifted, &a, 3).unwrap().unwrap();
        assert!(!first.warm_start);
        assert_eq!(lc.metrics().retrains_cold.get(), 1);
        assert!(lc.metrics().incremental_resyncs.get() >= 1, "reseed must count");
        // second drift: slides through the state machine, no retrain
        let b = shifted(256, 4);
        let second = lc.respond(DriftStatus::Drifted, &b, 5).unwrap().unwrap();
        assert!(second.warm_start, "incremental response continues the model");
        assert_ne!(first.id, second.id);
        assert_eq!(second.iterations, 256, "one slide per window row");
        assert_eq!(
            lc.metrics().incremental_updates.get(),
            512,
            "add + remove per slid row"
        );
        assert_eq!(
            lc.metrics().retrains_cold.get() + lc.metrics().retrains_warm.get(),
            1,
            "no retrain on the incremental path"
        );
        assert_eq!(lc.registry().champion().unwrap().unwrap().id, second.id);
        // non-drift statuses remain no-ops
        assert!(lc.respond(DriftStatus::Stable, &b, 6).unwrap().is_none());
        assert!(lc.respond(DriftStatus::Suspect, &b, 7).unwrap().is_none());
        std::fs::remove_dir_all(lc.registry().root()).ok();
    }

    #[test]
    fn respond_full_retrain_when_stale_budget_spent() {
        let mut lc = lifecycle("onlinestale").with_online(IncrementalConfig {
            stale_budget: 64,
            // keep since_resync deterministic: no divergence resyncs
            divergence_tol: 1e9,
            ..Default::default()
        });
        let a = Banana::default().generate(128, 6);
        lc.respond(DriftStatus::Drifted, &a, 1).unwrap().unwrap(); // seed (cold)
        let b = shifted(128, 7);
        lc.respond(DriftStatus::Drifted, &b, 2).unwrap().unwrap(); // incremental
        // 256 updates accumulated > budget 64: next drift retrains warm
        let third = lc.respond(DriftStatus::Drifted, &shifted(128, 8), 3).unwrap().unwrap();
        assert!(third.warm_start, "stale budget must trip a warm full retrain");
        assert_eq!(lc.metrics().retrains_warm.get(), 1);
        assert_eq!(lc.metrics().retrains_cold.get(), 1);
        // the reseeded machine takes the next drift incrementally again
        lc.respond(DriftStatus::Drifted, &shifted(128, 9), 4).unwrap().unwrap();
        assert_eq!(lc.metrics().retrains_warm.get(), 1, "reseed reset the budget");
        std::fs::remove_dir_all(lc.registry().root()).ok();
    }

    #[test]
    fn respond_without_online_is_observe() {
        let mut lc = lifecycle("respondobserve");
        let data = Banana::default().generate(1500, 2);
        assert!(lc.respond(DriftStatus::Stable, &data, 1).unwrap().is_none());
        let rep = lc.respond(DriftStatus::Drifted, &data, 3).unwrap().unwrap();
        assert_eq!(lc.registry().champion().unwrap().unwrap().id, rep.id);
        assert_eq!(lc.metrics().incremental_updates.get(), 0);
        std::fs::remove_dir_all(lc.registry().root()).ok();
    }

    #[test]
    fn retrain_swaps_attached_slot_and_rollback_restores() {
        let params = SvddParams::gaussian(0.35, 0.001);
        let cfg = SamplingConfig { sample_size: 6, ..Default::default() };
        let a = Banana::default().generate(2000, 3);
        let v1 = SamplingTrainer::new(params, cfg).train(&a, 5).unwrap().model;
        let slot = ModelSlot::new(v1.clone());
        let mut lc = Lifecycle::new(temp_registry("slot"), params, cfg).with_slot(slot.clone());

        // seed the registry with the serving model, then drift-retrain
        let r1 = lc.retrain(&a, 5).unwrap();
        let b = shifted(2000, 4);
        let r2 = lc.observe(DriftStatus::Drifted, &b, 9).unwrap().unwrap();
        assert_ne!(r1.id, r2.id);
        assert_eq!(r2.epoch, Some(slot.epoch()));
        // the slot now serves the drift-retrained model
        assert_eq!(slot.current().r2(), r2.r2);
        assert_eq!(lc.metrics().model_swaps.get(), 2);

        // rollback restores v1 in both registry and slot
        let back = lc.rollback().unwrap();
        assert_eq!(back, r1.id);
        assert_eq!(slot.current().content_id(), r1.id.as_str());
        std::fs::remove_dir_all(lc.registry().root()).ok();
    }

    #[test]
    fn sync_champion_follows_external_promotes() {
        let params = SvddParams::gaussian(0.35, 0.001);
        let cfg = SamplingConfig { sample_size: 6, ..Default::default() };
        let reg = temp_registry("sync");
        let a = Banana::default().generate(1500, 6);
        let b = shifted(1500, 7);
        let trainer = SamplingTrainer::new(params, cfg);
        let m1 = trainer.train(&a, 1).unwrap().model;
        let m2 = trainer.train(&b, 2).unwrap().model;
        let id1 = reg.publish(&m1, VersionMeta::new(&m1, &a)).unwrap();
        let id2 = reg.publish(&m2, VersionMeta::new(&m2, &b)).unwrap();

        let slot = ModelSlot::new(m1.clone());
        // nothing promoted yet: no-op
        assert!(sync_champion(&reg, &slot, None).unwrap().is_none());
        reg.promote(&id1).unwrap();
        // already serving id1's content, but the watcher has no `last`:
        // it swaps once and from then on reports unchanged
        assert_eq!(sync_champion(&reg, &slot, None).unwrap(), Some(id1.clone()));
        assert!(sync_champion(&reg, &slot, Some(&id1)).unwrap().is_none());
        reg.promote(&id2).unwrap();
        assert_eq!(sync_champion(&reg, &slot, Some(&id1)).unwrap(), Some(id2.clone()));
        assert_eq!(slot.current().content_id(), id2.as_str());
        std::fs::remove_dir_all(reg.root()).ok();
    }
}
