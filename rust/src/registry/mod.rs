//! **Model lifecycle subsystem**: versioned registry, warm-start
//! retraining and zero-downtime promotion into the serve path.
//!
//! The paper makes SVDD training cheap enough to retrain
//! *continuously*; this layer makes continuous retraining operable:
//!
//! - [`store::Registry`] — an on-disk, content-addressed model store
//!   with per-version training metadata, a champion pointer, atomic
//!   promote/rollback and pruning;
//! - [`version`] — content-addressed [`VersionId`]s (derived from
//!   [`SvddModel::content_hash`](crate::svdd::SvddModel::content_hash))
//!   plus the [`VersionMeta`] kept beside every version (`R^2`, `#SV`,
//!   sample size, iterations, warm/cold, bandwidth, data fingerprint);
//! - [`lifecycle::Lifecycle`] — the driver wiring
//!   [`DriftStatus::Drifted`](crate::sampling::DriftStatus) →
//!   warm-start retrain → publish → promote → hot-swap into a serving
//!   [`ModelSlot`](crate::scoring::ModelSlot).
//!
//! ## Registry directory layout
//!
//! ```text
//! <registry dir>/
//!   manifest.json        # {format, champion, history[], versions[]}
//!   models/
//!     v-<16 hex>.json    # one SvddModel JSON per version,
//!                        # content-addressed by FNV-1a model hash
//! ```
//!
//! The manifest is replaced atomically (write-temp + rename) and model
//! files land before the manifest references them, so concurrent
//! readers — e.g. `fastsvdd serve --registry DIR --watch`, which polls
//! the manifest and hot-swaps when the champion changes — always see a
//! consistent store.
//!
//! ## CLI
//!
//! ```text
//! fastsvdd train ... --registry DIR [--promote]   # publish a trained model
//! fastsvdd registry list     --dir DIR            # versions + champion marker
//! fastsvdd registry promote  --dir DIR --version v-<16 hex>
//! fastsvdd registry rollback --dir DIR            # restore previous champion
//! fastsvdd registry gc       --dir DIR --keep N   # prune old versions
//! fastsvdd serve --registry DIR --watch           # serve + follow champion
//! ```

pub mod lifecycle;
pub mod store;
pub mod version;

pub use lifecycle::{sync_champion, Lifecycle, LifecycleReport};
pub use store::{Registry, VersionEntry};
pub use version::{VersionId, VersionMeta};
