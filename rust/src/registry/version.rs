//! Version identity and per-version training metadata.
//!
//! Versions are *content-addressed*: the id is derived from the model's
//! [`content_hash`], so republishing an identical model is a no-op and
//! an id names the same boundary forever. Next to each version the
//! registry keeps the training metadata that matters operationally —
//! boundary quality (`R^2`, `#SV`), how the model was obtained (sample
//! size, iterations, warm vs cold start, bandwidth) and a fingerprint
//! of the training window — following Englhardt et al.
//! (arXiv:2009.13853) on keeping boundary-quality metadata with each
//! sample-trained SVDD.
//!
//! [`content_hash`]: crate::svdd::model::SvddModel::content_hash

use std::fmt;

use crate::error::{Error, Result};
use crate::sampling::SamplingOutcome;
use crate::svdd::model::SvddModel;
use crate::util::hash::fingerprint_matrix;
use crate::util::json::{num, obj, s, Json};
use crate::util::matrix::Matrix;

/// Content-addressed version id: `v-` + 16 lowercase hex digits of the
/// model's FNV-1a content hash.
#[derive(Clone, Debug, PartialEq, Eq, Hash, PartialOrd, Ord)]
pub struct VersionId(String);

impl VersionId {
    pub fn from_model(model: &SvddModel) -> VersionId {
        VersionId(model.content_id())
    }

    /// Validate an operator-supplied id string.
    pub fn parse(text: &str) -> Result<VersionId> {
        let hex = text.strip_prefix("v-").ok_or_else(|| {
            Error::Registry(format!("bad version id '{text}' (expected v-<16 hex>)"))
        })?;
        if hex.len() != 16 || !hex.bytes().all(|b| b.is_ascii_hexdigit() && !b.is_ascii_uppercase())
        {
            return Err(Error::Registry(format!(
                "bad version id '{text}' (expected v-<16 lowercase hex>)"
            )));
        }
        Ok(VersionId(text.to_string()))
    }

    pub fn as_str(&self) -> &str {
        &self.0
    }
}

impl fmt::Display for VersionId {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.0)
    }
}

/// Training metadata stored alongside each registry version.
#[derive(Clone, Debug, PartialEq)]
pub struct VersionMeta {
    /// Boundary threshold `R^2` of the stored model.
    pub r2: f64,
    pub num_sv: usize,
    pub dim: usize,
    /// Rows in the training window the model was fitted on.
    pub rows: usize,
    /// Algorithm-1 sample size `n` (0 when not sample-trained).
    pub sample_size: usize,
    /// Algorithm-1 iterations executed (0 when not sample-trained).
    pub iterations: usize,
    pub converged: bool,
    /// Whether `SV*` was seeded from the previous champion.
    pub warm_start: bool,
    /// Gaussian bandwidth (None for non-Gaussian kernels).
    pub bandwidth: Option<f64>,
    /// FNV-1a fingerprint of the training window (shape + bits).
    pub data_fingerprint: u64,
    /// Registration time, seconds since the Unix epoch.
    pub created_unix: u64,
}

fn now_unix() -> u64 {
    std::time::SystemTime::now()
        .duration_since(std::time::UNIX_EPOCH)
        .map(|d| d.as_secs())
        .unwrap_or(0)
}

impl VersionMeta {
    /// Metadata for a model trained outside the sampling loop (full
    /// SVDD baseline, CLI `--publish` glue, ...).
    pub fn new(model: &SvddModel, data: &Matrix) -> VersionMeta {
        VersionMeta {
            r2: model.r2(),
            num_sv: model.num_sv(),
            dim: model.dim(),
            rows: data.rows(),
            sample_size: 0,
            iterations: 0,
            converged: true,
            warm_start: false,
            bandwidth: model.kernel().bw(),
            data_fingerprint: fingerprint_matrix(data),
            created_unix: now_unix(),
        }
    }

    /// Metadata for an Algorithm-1 outcome (the pre-engine lifecycle
    /// path; kept for direct [`SamplingTrainer`] users).
    ///
    /// [`SamplingTrainer`]: crate::sampling::SamplingTrainer
    pub fn from_outcome(
        outcome: &SamplingOutcome,
        data: &Matrix,
        sample_size: usize,
    ) -> VersionMeta {
        VersionMeta {
            r2: outcome.model.r2(),
            num_sv: outcome.model.num_sv(),
            dim: outcome.model.dim(),
            rows: data.rows(),
            sample_size,
            iterations: outcome.iterations,
            converged: outcome.converged,
            warm_start: outcome.warm_start,
            bandwidth: outcome.model.kernel().bw(),
            data_fingerprint: fingerprint_matrix(data),
            created_unix: now_unix(),
        }
    }

    /// Metadata for a unified [`TrainReport`] — any method trained
    /// through [`crate::engine::Engine`] (the launcher + lifecycle
    /// path).
    ///
    /// [`TrainReport`]: crate::engine::TrainReport
    pub fn from_report(report: &crate::engine::TrainReport, data: &Matrix) -> VersionMeta {
        VersionMeta {
            r2: report.model.r2(),
            num_sv: report.model.num_sv(),
            dim: report.model.dim(),
            rows: data.rows(),
            sample_size: report.sample_size,
            iterations: report.iterations,
            converged: report.converged,
            warm_start: report.warm_start,
            bandwidth: report.model.kernel().bw(),
            data_fingerprint: fingerprint_matrix(data),
            created_unix: now_unix(),
        }
    }

    /// Reject metadata that cannot describe a servable model.
    pub fn validate(&self) -> Result<()> {
        if !self.r2.is_finite() {
            return Err(Error::Registry(format!("non-finite r2 {}", self.r2)));
        }
        if let Some(bw) = self.bandwidth {
            if !bw.is_finite() || bw <= 0.0 {
                return Err(Error::Registry(format!("bad bandwidth {bw}")));
            }
        }
        if self.num_sv == 0 || self.dim == 0 {
            return Err(Error::Registry("empty model metadata".into()));
        }
        Ok(())
    }

    pub fn to_json(&self) -> Json {
        obj(vec![
            ("r2", num(self.r2)),
            ("num_sv", num(self.num_sv as f64)),
            ("dim", num(self.dim as f64)),
            ("rows", num(self.rows as f64)),
            ("sample_size", num(self.sample_size as f64)),
            ("iterations", num(self.iterations as f64)),
            ("converged", Json::Bool(self.converged)),
            ("warm_start", Json::Bool(self.warm_start)),
            (
                "bandwidth",
                match self.bandwidth {
                    Some(bw) => num(bw),
                    None => Json::Null,
                },
            ),
            // u64 does not survive a round-trip through f64, so the
            // fingerprint is stored as fixed-width hex
            ("data_fingerprint", s(format!("{:016x}", self.data_fingerprint))),
            ("created_unix", num(self.created_unix as f64)),
        ])
    }

    pub fn from_json(v: &Json) -> Result<VersionMeta> {
        let f64_field = |key: &str| -> Result<f64> {
            let x = v
                .req(key)?
                .as_f64()
                .ok_or_else(|| Error::Registry(format!("'{key}' not a number")))?;
            if !x.is_finite() {
                return Err(Error::Registry(format!("non-finite '{key}': {x}")));
            }
            Ok(x)
        };
        let usize_field = |key: &str| -> Result<usize> { f64_field(key).map(|x| x as usize) };
        let bool_field = |key: &str| -> Result<bool> {
            v.req(key)?
                .as_bool()
                .ok_or_else(|| Error::Registry(format!("'{key}' not a bool")))
        };
        let bandwidth = match v.req("bandwidth")? {
            Json::Null => None,
            j => {
                let bw = j
                    .as_f64()
                    .ok_or_else(|| Error::Registry("'bandwidth' not a number".into()))?;
                Some(bw)
            }
        };
        let fp_hex = v
            .req("data_fingerprint")?
            .as_str()
            .ok_or_else(|| Error::Registry("'data_fingerprint' not a string".into()))?;
        let data_fingerprint = u64::from_str_radix(fp_hex, 16)
            .map_err(|_| Error::Registry(format!("bad fingerprint '{fp_hex}'")))?;
        let meta = VersionMeta {
            r2: f64_field("r2")?,
            num_sv: usize_field("num_sv")?,
            dim: usize_field("dim")?,
            rows: usize_field("rows")?,
            sample_size: usize_field("sample_size")?,
            iterations: usize_field("iterations")?,
            converged: bool_field("converged")?,
            warm_start: bool_field("warm_start")?,
            bandwidth,
            data_fingerprint,
            created_unix: f64_field("created_unix")? as u64,
        };
        meta.validate()?;
        Ok(meta)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_meta() -> VersionMeta {
        VersionMeta {
            r2: 0.8437,
            num_sv: 23,
            dim: 2,
            rows: 4096,
            sample_size: 6,
            iterations: 31,
            converged: true,
            warm_start: true,
            bandwidth: Some(0.35),
            data_fingerprint: 0xdead_beef_0123_4567,
            created_unix: 1_753_000_000,
        }
    }

    #[test]
    fn id_parse_accepts_canonical_rejects_junk() {
        let id = VersionId::parse("v-00ff00ff00ff00ff").unwrap();
        assert_eq!(id.as_str(), "v-00ff00ff00ff00ff");
        assert!(VersionId::parse("v-00FF00FF00FF00FF").is_err()); // uppercase
        assert!(VersionId::parse("v-123").is_err()); // short
        assert!(VersionId::parse("w-00ff00ff00ff00ff").is_err()); // prefix
        assert!(VersionId::parse("v-00ff00ff00ff00fg").is_err()); // non-hex
    }

    #[test]
    fn meta_roundtrips_exactly() {
        let meta = sample_meta();
        let text = meta.to_json().to_string_pretty();
        let back = VersionMeta::from_json(&Json::parse(&text).unwrap()).unwrap();
        assert_eq!(back, meta);
    }

    #[test]
    fn meta_roundtrip_preserves_full_u64_fingerprint() {
        let mut meta = sample_meta();
        meta.data_fingerprint = u64::MAX; // would lose bits as f64
        let back =
            VersionMeta::from_json(&Json::parse(&meta.to_json().to_string()).unwrap()).unwrap();
        assert_eq!(back.data_fingerprint, u64::MAX);
    }

    #[test]
    fn meta_rejects_non_finite_and_empty() {
        let mut bad = sample_meta();
        bad.r2 = f64::NAN;
        assert!(bad.validate().is_err());
        let mut bad = sample_meta();
        bad.bandwidth = Some(f64::INFINITY);
        assert!(bad.validate().is_err());
        let mut bad = sample_meta();
        bad.num_sv = 0;
        assert!(bad.validate().is_err());
        // JSON cannot spell NaN, but it can spell an overflowing number
        let j = Json::parse(
            r#"{"r2": 1e999, "num_sv": 1, "dim": 1, "rows": 1, "sample_size": 0,
                "iterations": 0, "converged": true, "warm_start": false,
                "bandwidth": null, "data_fingerprint": "00000000000000aa",
                "created_unix": 0}"#,
        )
        .unwrap();
        assert!(VersionMeta::from_json(&j).is_err());
    }
}
