//! On-disk versioned model store.
//!
//! Directory layout (everything human-inspectable JSON):
//!
//! ```text
//! registry/
//!   manifest.json          # format tag, champion pointer, promote
//!                          # history, one entry per version (id + meta)
//!   models/
//!     v-<16 hex>.json      # SvddModel::to_json, content-addressed
//! ```
//!
//! Writes are crash-safe: model files are content-addressed (a partial
//! write is simply re-written on retry; ids never dangle because the
//! manifest is updated *after* the model file lands), and the manifest
//! itself is replaced atomically via write-to-temp + rename. Readers
//! (e.g. `fastsvdd serve --registry --watch` polling for a new
//! champion) therefore always observe a complete manifest.
//!
//! The store is single-writer: one lifecycle driver / operator CLI at a
//! time. Concurrent readers are fine.

use std::path::{Path, PathBuf};

use crate::error::{Error, Result};
use crate::registry::version::{VersionId, VersionMeta};
use crate::svdd::model::SvddModel;
use crate::util::json::{arr, obj, s, Json};

const MANIFEST_FORMAT: &str = "fastsvdd-registry-v1";

/// Rollback depth: promote keeps at most this many previous champions
/// on the history. Without a bound, a continuously retraining
/// lifecycle would pin every ex-champion forever and [`Registry::gc`]
/// could never reclaim disk.
const MAX_HISTORY: usize = 8;

/// One registered version: id + training metadata.
#[derive(Clone, Debug, PartialEq)]
pub struct VersionEntry {
    pub id: VersionId,
    pub meta: VersionMeta,
}

/// Parsed manifest state (internal; the public API re-reads per call so
/// external promotes/gcs are always observed).
#[derive(Clone, Debug, Default)]
struct ManifestData {
    /// Currently served version, if any.
    champion: Option<VersionId>,
    /// Previous champions, oldest first (rollback pops from the back).
    history: Vec<VersionId>,
    /// All live versions in publish order.
    entries: Vec<VersionEntry>,
}

/// Handle on a registry directory.
pub struct Registry {
    root: PathBuf,
}

impl Registry {
    /// Open (creating if needed) a registry rooted at `root`.
    pub fn open(root: impl AsRef<Path>) -> Result<Registry> {
        let root = root.as_ref().to_path_buf();
        std::fs::create_dir_all(root.join("models"))?;
        let reg = Registry { root };
        if !reg.manifest_path().exists() {
            reg.write_manifest(&ManifestData::default())?;
        }
        Ok(reg)
    }

    pub fn root(&self) -> &Path {
        &self.root
    }

    fn manifest_path(&self) -> PathBuf {
        self.root.join("manifest.json")
    }

    fn model_path(&self, id: &VersionId) -> PathBuf {
        self.root.join("models").join(format!("{id}.json"))
    }

    // ------------------------------------------------------- manifest io

    fn read_manifest(&self) -> Result<ManifestData> {
        let text = std::fs::read_to_string(self.manifest_path())?;
        let v = Json::parse(&text)?;
        if v.req("format")?.as_str() != Some(MANIFEST_FORMAT) {
            return Err(Error::Registry(format!(
                "unknown manifest format in {}",
                self.manifest_path().display()
            )));
        }
        let champion = match v.req("champion")? {
            Json::Null => None,
            j => Some(VersionId::parse(j.as_str().ok_or_else(|| {
                Error::Registry("'champion' not a string".into())
            })?)?),
        };
        let mut history = Vec::new();
        for j in v
            .req("history")?
            .as_arr()
            .ok_or_else(|| Error::Registry("'history' not an array".into()))?
        {
            history.push(VersionId::parse(j.as_str().ok_or_else(|| {
                Error::Registry("history entry not a string".into())
            })?)?);
        }
        let mut entries = Vec::new();
        for j in v
            .req("versions")?
            .as_arr()
            .ok_or_else(|| Error::Registry("'versions' not an array".into()))?
        {
            let id = VersionId::parse(
                j.req("id")?
                    .as_str()
                    .ok_or_else(|| Error::Registry("version 'id' not a string".into()))?,
            )?;
            let meta = VersionMeta::from_json(j.req("meta")?)?;
            entries.push(VersionEntry { id, meta });
        }
        Ok(ManifestData { champion, history, entries })
    }

    fn write_manifest(&self, m: &ManifestData) -> Result<()> {
        let versions = m
            .entries
            .iter()
            .map(|e| obj(vec![("id", s(e.id.as_str())), ("meta", e.meta.to_json())]))
            .collect();
        let doc = obj(vec![
            ("format", s(MANIFEST_FORMAT)),
            (
                "champion",
                match &m.champion {
                    Some(id) => s(id.as_str()),
                    None => Json::Null,
                },
            ),
            (
                "history",
                arr(m.history.iter().map(|id| s(id.as_str())).collect()),
            ),
            ("versions", arr(versions)),
        ]);
        let path = self.manifest_path();
        let tmp = self.root.join("manifest.json.tmp");
        std::fs::write(&tmp, doc.to_string_pretty())?;
        std::fs::rename(&tmp, &path)?;
        Ok(())
    }

    // ------------------------------------------------------- operations

    /// Register a model version (content-addressed; publishing the same
    /// model twice yields the same id, and the stored metadata is
    /// refreshed to describe the *latest* training run — a warm retrain
    /// that reconverges to identical content should still report its
    /// own iterations/fingerprint/timestamp). Does **not** change the
    /// champion — promotion is a separate, explicit step.
    pub fn publish(&self, model: &SvddModel, meta: VersionMeta) -> Result<VersionId> {
        meta.validate()?;
        let id = VersionId::from_model(model);
        // model file first, manifest second: a crash in between leaves
        // an orphan file, never a dangling manifest entry
        let path = self.model_path(&id);
        if !path.exists() {
            let tmp = path.with_extension("json.tmp");
            std::fs::write(&tmp, model.to_json().to_string_pretty())?;
            std::fs::rename(&tmp, &path)?;
        }
        let mut m = self.read_manifest()?;
        match m.entries.iter_mut().find(|e| e.id == id) {
            Some(entry) => entry.meta = meta,
            None => m.entries.push(VersionEntry { id: id.clone(), meta }),
        }
        self.write_manifest(&m)?;
        Ok(id)
    }

    /// Make `id` the champion. The previous champion (if different) is
    /// pushed onto the rollback history, which is capped at
    /// [`MAX_HISTORY`] entries (oldest dropped) so continuous
    /// promotion cannot pin unbounded disk.
    pub fn promote(&self, id: &VersionId) -> Result<()> {
        let mut m = self.read_manifest()?;
        if !m.entries.iter().any(|e| &e.id == id) {
            return Err(Error::Registry(format!("cannot promote unknown version {id}")));
        }
        match &m.champion {
            Some(current) if current == id => return Ok(()), // already champion
            Some(current) => {
                let prev = current.clone();
                m.history.push(prev);
                if m.history.len() > MAX_HISTORY {
                    let excess = m.history.len() - MAX_HISTORY;
                    m.history.drain(..excess);
                }
            }
            None => {}
        }
        m.champion = Some(id.clone());
        self.write_manifest(&m)
    }

    /// The version [`Registry::rollback`] would restore, without
    /// changing anything (callers validate servability first).
    pub fn peek_rollback(&self) -> Result<Option<VersionId>> {
        Ok(self.read_manifest()?.history.last().cloned())
    }

    /// Restore the previous champion (pop the rollback history).
    /// Returns the version now serving as champion.
    pub fn rollback(&self) -> Result<VersionId> {
        let mut m = self.read_manifest()?;
        let prev = m
            .history
            .pop()
            .ok_or_else(|| Error::Registry("nothing to roll back to".into()))?;
        if !m.entries.iter().any(|e| e.id == prev) {
            return Err(Error::Registry(format!(
                "previous champion {prev} was pruned; cannot roll back"
            )));
        }
        m.champion = Some(prev.clone());
        self.write_manifest(&m)?;
        Ok(prev)
    }

    /// The champion entry, if one was promoted.
    pub fn champion(&self) -> Result<Option<VersionEntry>> {
        let m = self.read_manifest()?;
        Ok(match m.champion {
            Some(id) => m.entries.into_iter().find(|e| e.id == id),
            None => None,
        })
    }

    /// Load the champion model (id + deserialized model).
    pub fn champion_model(&self) -> Result<Option<(VersionId, SvddModel)>> {
        match self.champion()? {
            Some(entry) => {
                let model = self.load(&entry.id)?;
                Ok(Some((entry.id, model)))
            }
            None => Ok(None),
        }
    }

    /// Load a specific version's model.
    pub fn load(&self, id: &VersionId) -> Result<SvddModel> {
        let path = self.model_path(id);
        let text = std::fs::read_to_string(&path).map_err(|e| {
            Error::Registry(format!("version {id} has no model file ({e})"))
        })?;
        let model = SvddModel::from_json(&Json::parse(&text)?)?;
        // content addressing means the file must hash to its own name
        let actual = VersionId::from_model(&model);
        if &actual != id {
            return Err(Error::Registry(format!(
                "corrupt model file for {id}: content hashes to {actual}"
            )));
        }
        Ok(model)
    }

    /// Metadata lookup for one version.
    pub fn get(&self, id: &VersionId) -> Result<VersionEntry> {
        self.read_manifest()?
            .entries
            .into_iter()
            .find(|e| &e.id == id)
            .ok_or_else(|| Error::Registry(format!("unknown version {id}")))
    }

    /// All versions in publish order.
    pub fn list(&self) -> Result<Vec<VersionEntry>> {
        Ok(self.read_manifest()?.entries)
    }

    /// Prune old versions, keeping the champion, everything on the
    /// rollback history, and the `keep` most recently published
    /// entries. Deletes pruned model files (plus any orphaned model
    /// files from interrupted publishes) and returns the pruned ids.
    pub fn gc(&self, keep: usize) -> Result<Vec<VersionId>> {
        let mut m = self.read_manifest()?;
        let entries = std::mem::take(&mut m.entries);
        let cutoff = entries.len().saturating_sub(keep);
        let mut pruned = Vec::new();
        let mut kept = Vec::new();
        for (i, e) in entries.into_iter().enumerate() {
            let pinned = Some(&e.id) == m.champion.as_ref() || m.history.contains(&e.id);
            if i < cutoff && !pinned {
                pruned.push(e.id);
            } else {
                kept.push(e);
            }
        }
        m.entries = kept;
        self.write_manifest(&m)?;
        for id in &pruned {
            std::fs::remove_file(self.model_path(id)).ok();
        }
        // sweep orphans: anything under models/ no manifest entry
        // refers to — including `.json.tmp` leftovers from a publish
        // interrupted between write and rename
        let live: std::collections::HashSet<PathBuf> =
            m.entries.iter().map(|e| self.model_path(&e.id)).collect();
        if let Ok(dir) = std::fs::read_dir(self.root.join("models")) {
            for f in dir.flatten() {
                let p = f.path();
                if p.is_file() && !live.contains(&p) {
                    std::fs::remove_file(&p).ok();
                }
            }
        }
        std::fs::remove_file(self.root.join("manifest.json.tmp")).ok();
        Ok(pruned)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{banana::Banana, Generator};
    use crate::svdd::{train, SvddParams};
    use crate::util::matrix::Matrix;

    fn temp_registry(tag: &str) -> Registry {
        let dir = std::env::temp_dir().join(format!(
            "fastsvdd_registry_{tag}_{}",
            std::process::id()
        ));
        std::fs::remove_dir_all(&dir).ok();
        Registry::open(&dir).unwrap()
    }

    fn toy_model(seed: u64) -> (SvddModel, Matrix) {
        let data = Banana::default().generate(300 + seed as usize, seed);
        let model = train(&data, &SvddParams::gaussian(0.35, 0.01)).unwrap();
        (model, data)
    }

    #[test]
    fn publish_promote_champion_roundtrip() {
        let reg = temp_registry("ppc");
        assert!(reg.champion().unwrap().is_none());
        let (m1, d1) = toy_model(1);
        let id1 = reg.publish(&m1, VersionMeta::new(&m1, &d1)).unwrap();
        assert_eq!(id1.as_str(), m1.content_id());
        // publish without promote: still no champion
        assert!(reg.champion().unwrap().is_none());
        reg.promote(&id1).unwrap();
        let (cid, cm) = reg.champion_model().unwrap().unwrap();
        assert_eq!(cid, id1);
        assert_eq!(cm.content_hash(), m1.content_hash());
        // scoring via the reloaded champion is bit-identical
        let z = [0.2, -0.4];
        assert_eq!(cm.dist2(&z), m1.dist2(&z));
        std::fs::remove_dir_all(reg.root()).ok();
    }

    #[test]
    fn republish_is_idempotent_and_refreshes_meta() {
        let reg = temp_registry("idem");
        let (m, d) = toy_model(2);
        let a = reg.publish(&m, VersionMeta::new(&m, &d)).unwrap();
        let b = reg.publish(&m, VersionMeta::new(&m, &d)).unwrap();
        assert_eq!(a, b);
        assert_eq!(reg.list().unwrap().len(), 1);
        // a warm retrain reconverging to identical content must update
        // the stored training record, not keep the stale one
        let mut warm_meta = VersionMeta::new(&m, &d);
        warm_meta.warm_start = true;
        warm_meta.iterations = 9;
        reg.publish(&m, warm_meta).unwrap();
        let entry = reg.get(&a).unwrap();
        assert!(entry.meta.warm_start);
        assert_eq!(entry.meta.iterations, 9);
        assert_eq!(reg.list().unwrap().len(), 1);
        std::fs::remove_dir_all(reg.root()).ok();
    }

    #[test]
    fn promote_unknown_rejected() {
        let reg = temp_registry("unknown");
        let id = VersionId::parse("v-0123456789abcdef").unwrap();
        assert!(reg.promote(&id).is_err());
        std::fs::remove_dir_all(reg.root()).ok();
    }

    #[test]
    fn rollback_restores_previous_champion() {
        let reg = temp_registry("rollback");
        let (m1, d1) = toy_model(3);
        let (m2, d2) = toy_model(4);
        let id1 = reg.publish(&m1, VersionMeta::new(&m1, &d1)).unwrap();
        let id2 = reg.publish(&m2, VersionMeta::new(&m2, &d2)).unwrap();
        assert_ne!(id1, id2);
        reg.promote(&id1).unwrap();
        reg.promote(&id2).unwrap();
        assert_eq!(reg.champion().unwrap().unwrap().id, id2);
        let back = reg.rollback().unwrap();
        assert_eq!(back, id1);
        assert_eq!(reg.champion().unwrap().unwrap().id, id1);
        // nothing further to roll back to
        assert!(reg.rollback().is_err());
        std::fs::remove_dir_all(reg.root()).ok();
    }

    #[test]
    fn state_survives_reopen() {
        let reg = temp_registry("reopen");
        let (m1, d1) = toy_model(5);
        let id1 = reg.publish(&m1, VersionMeta::new(&m1, &d1)).unwrap();
        reg.promote(&id1).unwrap();
        let root = reg.root().to_path_buf();
        drop(reg);
        let reg2 = Registry::open(&root).unwrap();
        let (cid, cm) = reg2.champion_model().unwrap().unwrap();
        assert_eq!(cid, id1);
        assert_eq!(cm.num_sv(), m1.num_sv());
        assert_eq!(reg2.get(&id1).unwrap().meta.rows, d1.rows());
        std::fs::remove_dir_all(&root).ok();
    }

    #[test]
    fn gc_keeps_champion_history_and_recent() {
        let reg = temp_registry("gc");
        let mut ids = Vec::new();
        for seed in 10..16 {
            let (m, d) = toy_model(seed);
            ids.push(reg.publish(&m, VersionMeta::new(&m, &d)).unwrap());
        }
        // champion = ids[0] (oldest), history gets ids[1]
        reg.promote(&ids[1]).unwrap();
        reg.promote(&ids[0]).unwrap();
        let pruned = reg.gc(1).unwrap();
        // ids[0] champion, ids[1] history, ids[5] most recent → survive
        let left: Vec<_> = reg.list().unwrap().into_iter().map(|e| e.id).collect();
        assert!(left.contains(&ids[0]));
        assert!(left.contains(&ids[1]));
        assert!(left.contains(&ids[5]));
        assert_eq!(left.len(), 3);
        assert_eq!(pruned.len(), 3);
        for id in &pruned {
            assert!(reg.load(id).is_err(), "pruned model file should be gone");
        }
        // pinned versions still load
        assert!(reg.load(&ids[0]).is_ok());
        std::fs::remove_dir_all(reg.root()).ok();
    }

    #[test]
    fn history_is_bounded_so_gc_can_reclaim() {
        let reg = temp_registry("histcap");
        let mut ids = Vec::new();
        for seed in 30..30 + (MAX_HISTORY as u64 + 4) {
            let (m, d) = toy_model(seed);
            let id = reg.publish(&m, VersionMeta::new(&m, &d)).unwrap();
            reg.promote(&id).unwrap();
            ids.push(id);
        }
        // champion + at most MAX_HISTORY pinned: gc(1) must reclaim the
        // oldest ex-champions instead of pinning every one forever
        let pruned = reg.gc(1).unwrap();
        assert!(
            !pruned.is_empty(),
            "continuous promotion must not pin every version"
        );
        let left = reg.list().unwrap().len();
        assert!(left <= MAX_HISTORY + 1, "{left} versions survived gc");
        // the champion and the most recent history survive; rollback works
        assert_eq!(reg.champion().unwrap().unwrap().id, *ids.last().unwrap());
        assert_eq!(reg.rollback().unwrap(), ids[ids.len() - 2]);
        std::fs::remove_dir_all(reg.root()).ok();
    }

    #[test]
    fn gc_sweeps_interrupted_publish_tmp_files() {
        let reg = temp_registry("tmpsweep");
        let (m, d) = toy_model(40);
        reg.publish(&m, VersionMeta::new(&m, &d)).unwrap();
        // simulate a publish that crashed between write and rename
        let orphan = reg.root().join("models").join("v-00000000deadbeef.json.tmp");
        std::fs::write(&orphan, "{").unwrap();
        reg.gc(10).unwrap();
        assert!(!orphan.exists(), "interrupted-publish tmp file not swept");
        // the live model survived
        assert_eq!(reg.list().unwrap().len(), 1);
        std::fs::remove_dir_all(reg.root()).ok();
    }

    #[test]
    fn corrupt_model_file_detected() {
        let reg = temp_registry("corrupt");
        let (m1, d1) = toy_model(20);
        let (m2, _) = toy_model(21);
        let id1 = reg.publish(&m1, VersionMeta::new(&m1, &d1)).unwrap();
        // overwrite id1's file with a different model's bytes
        std::fs::write(
            reg.model_path(&id1),
            m2.to_json().to_string_pretty(),
        )
        .unwrap();
        let err = reg.load(&id1).unwrap_err();
        assert!(err.to_string().contains("corrupt"), "{err}");
        std::fs::remove_dir_all(reg.root()).ok();
    }
}
