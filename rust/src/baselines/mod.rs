//! Baseline trainers the paper compares against (section III):
//!
//! - [`full`] — the "full SVDD method": one solve over all observations
//!   (what Table I / Fig 1 measure);
//! - [`luo`] — Luo et al. [7], decomposition + combination: needs one
//!   full-data scoring pass per iteration (the cost the paper removes);
//! - [`kim`] — Kim et al. [5], k-means divide-and-conquer: touches every
//!   observation (built on our own Lloyd's k-means in [`kmeans`]).

pub mod full;
pub mod kim;
pub mod kmeans;
pub mod luo;

pub use full::train_full;
pub use kim::{train_kim, KimConfig};
pub use luo::{train_luo, LuoConfig};
