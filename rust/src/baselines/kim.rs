//! Kim et al. [5]: "Fast support vector data description using k-means
//! clustering" — the divide-and-conquer baseline.
//!
//! 1. Partition the data into `k` clusters (Lloyd's k-means).
//! 2. Train SVDD on each cluster; collect its support vectors.
//! 3. Train a final SVDD on the union of all cluster SVs.
//!
//! The paper criticizes this method because every observation
//! participates in step 1 + step 2 (it "uses each observation from the
//! training data set to arrive at the final solution").

use crate::error::Result;
use crate::svdd::model::SvddModel;
use crate::svdd::trainer::{train_detailed, SolverStats, SvddParams};
use crate::util::matrix::Matrix;

use super::kmeans::kmeans;

#[derive(Clone, Copy, Debug)]
pub struct KimConfig {
    /// Number of k-means clusters.
    pub clusters: usize,
    /// Lloyd iteration cap.
    pub kmeans_iters: usize,
    pub seed: u64,
}

impl Default for KimConfig {
    fn default() -> Self {
        KimConfig { clusters: 8, kmeans_iters: 50, seed: 0 }
    }
}

#[derive(Clone, Debug)]
pub struct KimOutcome {
    pub model: SvddModel,
    /// SVs pooled from the per-cluster solves (before the final solve).
    pub pooled_svs: usize,
    /// SMO solves issued (one per non-empty cluster + the final solve).
    pub solver_calls: usize,
    /// Observations fed to solvers (every row once + the pooled SVs).
    pub rows_touched: usize,
    /// Aggregated SMO telemetry across every solve of the run.
    pub solver: SolverStats,
}

/// Run the Kim et al. baseline.
pub fn train_kim(data: &Matrix, params: &SvddParams, cfg: &KimConfig) -> Result<KimOutcome> {
    let km = kmeans(data, cfg.clusters, cfg.kmeans_iters, cfg.seed);
    let k = km.centroids.rows();
    let mut solver = SolverStats::default();
    let mut solver_calls = 0usize;
    let mut rows_touched = 0usize;
    let mut pooled = Matrix::zeros(0, data.cols());
    for c in 0..k {
        let idx: Vec<usize> = (0..data.rows()).filter(|&i| km.assignment[i] == c).collect();
        if idx.is_empty() {
            continue;
        }
        let chunk = data.gather(&idx);
        let (model, stats) = train_detailed(&chunk, params, None)?;
        solver.absorb(&stats);
        solver_calls += 1;
        rows_touched += chunk.rows();
        pooled = pooled.vstack(model.support_vectors())?;
    }
    let pooled = pooled.dedup_rows();
    let pooled_svs = pooled.rows();
    let (model, stats) = train_detailed(&pooled, params, None)?;
    solver.absorb(&stats);
    solver_calls += 1;
    rows_touched += pooled.rows();
    Ok(KimOutcome { model, pooled_svs, solver_calls, rows_touched, solver })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{donut::TwoDonut, Generator};

    #[test]
    fn kim_close_to_full_on_two_donut() {
        let data = TwoDonut::default().generate(3000, 4);
        let params = SvddParams::gaussian(0.4, 0.001);
        let full = crate::svdd::train(&data, &params).unwrap();
        let kim = train_kim(&data, &params, &KimConfig::default()).unwrap();
        let rel = (kim.model.r2() - full.r2()).abs() / full.r2();
        assert!(rel < 0.1, "R^2 gap {rel}");
        assert!(kim.pooled_svs >= kim.model.num_sv());
        // telemetry: every row fed to exactly one cluster solve, the
        // pooled SVs to the final one
        assert_eq!(kim.rows_touched, data.rows() + kim.pooled_svs);
        assert!(kim.solver_calls >= 2);
        assert!(kim.solver.smo_iterations > 0);
    }

    #[test]
    fn single_cluster_equals_full() {
        let data = TwoDonut::default().generate(400, 5);
        let params = SvddParams::gaussian(0.4, 0.01);
        let full = crate::svdd::train(&data, &params).unwrap();
        let cfg = KimConfig { clusters: 1, ..Default::default() };
        let kim = train_kim(&data, &params, &cfg).unwrap();
        // one cluster -> same SV pool modulo the double solve
        assert!((kim.model.r2() - full.r2()).abs() / full.r2() < 0.05);
    }
}
