//! Lloyd's k-means — the clustering substrate for Kim et al.'s
//! divide-and-conquer SVDD baseline (no clustering crate in the
//! vendored set, so built from scratch). k-means++ seeding, fixed
//! iteration cap, deterministic under a seed.

use crate::util::matrix::Matrix;
use crate::util::rng::Xoshiro256;

/// k-means result: per-point assignment + centroids.
#[derive(Clone, Debug)]
pub struct KMeans {
    pub assignment: Vec<usize>,
    pub centroids: Matrix,
    pub iterations: usize,
}

/// Run Lloyd's algorithm with k-means++ seeding.
pub fn kmeans(data: &Matrix, k: usize, max_iter: usize, seed: u64) -> KMeans {
    let n = data.rows();
    let k = k.max(1).min(n);
    let mut rng = Xoshiro256::new(seed);

    // --- k-means++ seeding ---
    let mut centers: Vec<Vec<f64>> = Vec::with_capacity(k);
    centers.push(data.row(rng.index(n)).to_vec());
    let mut d2 = vec![f64::INFINITY; n];
    while centers.len() < k {
        let last = centers.last().unwrap();
        let mut total = 0.0;
        for i in 0..n {
            let d = Matrix::sqdist(data.row(i), last);
            if d < d2[i] {
                d2[i] = d;
            }
            total += d2[i];
        }
        let pick = if total <= 0.0 {
            rng.index(n)
        } else {
            let mut target = rng.f64() * total;
            let mut idx = n - 1;
            for (i, &d) in d2.iter().enumerate() {
                target -= d;
                if target <= 0.0 {
                    idx = i;
                    break;
                }
            }
            idx
        };
        centers.push(data.row(pick).to_vec());
    }

    // --- Lloyd iterations ---
    let mut assignment = vec![0usize; n];
    let mut iterations = 0;
    for it in 0..max_iter {
        iterations = it + 1;
        let mut changed = false;
        for i in 0..n {
            let mut best = 0;
            let mut best_d = f64::INFINITY;
            for (c, center) in centers.iter().enumerate() {
                let d = Matrix::sqdist(data.row(i), center);
                if d < best_d {
                    best_d = d;
                    best = c;
                }
            }
            if assignment[i] != best {
                assignment[i] = best;
                changed = true;
            }
        }
        if !changed && it > 0 {
            break;
        }
        // recompute centroids (empty cluster keeps its previous center)
        let mut sums = vec![vec![0.0; data.cols()]; centers.len()];
        let mut counts = vec![0usize; centers.len()];
        for i in 0..n {
            counts[assignment[i]] += 1;
            for (s, v) in sums[assignment[i]].iter_mut().zip(data.row(i)) {
                *s += v;
            }
        }
        for (c, center) in centers.iter_mut().enumerate() {
            if counts[c] > 0 {
                for (cv, sv) in center.iter_mut().zip(&sums[c]) {
                    *cv = sv / counts[c] as f64;
                }
            }
        }
    }

    KMeans {
        assignment,
        centroids: Matrix::from_rows(&centers).unwrap(),
        iterations,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn two_blobs(n: usize) -> Matrix {
        let mut rng = Xoshiro256::new(5);
        let rows: Vec<Vec<f64>> = (0..n)
            .map(|i| {
                let cx = if i % 2 == 0 { -5.0 } else { 5.0 };
                vec![cx + rng.normal() * 0.5, rng.normal() * 0.5]
            })
            .collect();
        Matrix::from_rows(&rows).unwrap()
    }

    #[test]
    fn separates_two_blobs() {
        let data = two_blobs(400);
        let km = kmeans(&data, 2, 100, 1);
        // all even-index points together, all odd together
        let a0 = km.assignment[0];
        for i in (0..400).step_by(2) {
            assert_eq!(km.assignment[i], a0);
        }
        for i in (1..400).step_by(2) {
            assert_ne!(km.assignment[i], a0);
        }
        // centroids near +-5
        let cx: Vec<f64> = (0..2).map(|c| km.centroids.get(c, 0)).collect();
        assert!((cx[0].abs() - 5.0).abs() < 0.5);
        assert!((cx[1].abs() - 5.0).abs() < 0.5);
    }

    #[test]
    fn k_clamped_to_n() {
        let data = two_blobs(4);
        let km = kmeans(&data, 100, 10, 2);
        assert!(km.centroids.rows() <= 4);
        assert!(km.assignment.iter().all(|&a| a < km.centroids.rows()));
    }

    #[test]
    fn deterministic() {
        let data = two_blobs(100);
        let a = kmeans(&data, 3, 50, 9);
        let b = kmeans(&data, 3, 50, 9);
        assert_eq!(a.assignment, b.assignment);
    }

    #[test]
    fn single_cluster_centroid_is_mean() {
        let data = two_blobs(50);
        let km = kmeans(&data, 1, 10, 3);
        let means = data.col_means();
        for j in 0..data.cols() {
            assert!((km.centroids.get(0, j) - means[j]).abs() < 1e-9);
        }
    }
}
