//! Luo et al. [7]: "A fast SVDD algorithm based on decomposition and
//! combination" — the iterative baseline whose per-iteration
//! **full-data scoring pass** the paper's method eliminates.
//!
//! 1. Split the data into chunks; train SVDD per chunk; pool the SVs
//!    into a working set.
//! 2. Iterate: train SVDD on the working set, score *all* observations,
//!    add the violators (outside the description) to the working set.
//! 3. Stop when (almost) no violators remain or after `max_rounds`.

use crate::error::Result;
use crate::svdd::model::SvddModel;
use crate::svdd::trainer::{train_detailed, SolverStats, SvddParams};
use crate::util::matrix::Matrix;

#[derive(Clone, Copy, Debug)]
pub struct LuoConfig {
    /// Decomposition chunk size.
    pub chunk: usize,
    /// Violators added per round (cap, most-violating first).
    pub add_per_round: usize,
    /// Combination round cap.
    pub max_rounds: usize,
    /// Slack on the radius when testing violation.
    pub margin: f64,
}

impl Default for LuoConfig {
    fn default() -> Self {
        LuoConfig { chunk: 256, add_per_round: 64, max_rounds: 50, margin: 1e-9 }
    }
}

#[derive(Clone, Debug)]
pub struct LuoOutcome {
    pub model: SvddModel,
    /// Combination rounds executed.
    pub rounds: usize,
    /// Full-data scoring passes performed (== rounds; the method's
    /// structural cost).
    pub scoring_passes: usize,
    /// Whether the combination emptied the violator set (vs hitting
    /// `max_rounds` with violators left).
    pub converged: bool,
    /// SMO solves issued (decomposition chunks + combination rounds).
    pub solver_calls: usize,
    /// Observations fed to solvers across all solves.
    pub rows_touched: usize,
    /// Aggregated SMO telemetry across every solve of the run.
    pub solver: SolverStats,
}

/// Run the Luo et al. baseline.
pub fn train_luo(data: &Matrix, params: &SvddParams, cfg: &LuoConfig) -> Result<LuoOutcome> {
    let n = data.rows();
    let mut solver = SolverStats::default();
    let mut solver_calls = 0usize;
    let mut rows_touched = 0usize;
    // --- decomposition ---
    let mut working: Vec<usize> = Vec::new();
    let mut start = 0;
    while start < n {
        let end = (start + cfg.chunk).min(n);
        let idx: Vec<usize> = (start..end).collect();
        let chunk = data.gather(&idx);
        let (model, stats) = train_detailed(&chunk, params, None)?;
        solver.absorb(&stats);
        solver_calls += 1;
        rows_touched += chunk.rows();
        // recover the chunk-local SV indices by re-scoring alphas: we
        // know SVs are exact rows of the chunk, so match by position.
        // (train() gathers rows in order, so match sequentially.)
        let mut j = 0;
        for (local, global) in idx.iter().enumerate() {
            if j < model.num_sv()
                && chunk.row(local) == model.support_vectors().row(j)
            {
                working.push(*global);
                j += 1;
            }
        }
        start = end;
    }
    working.sort_unstable();
    working.dedup();

    // --- combination ---
    let mut rounds = 0;
    let mut converged = false;
    let ws = data.gather(&working);
    let (mut model, stats) = train_detailed(&ws, params, None)?;
    solver.absorb(&stats);
    solver_calls += 1;
    rows_touched += ws.rows();
    for _ in 0..cfg.max_rounds {
        rounds += 1;
        // the full-data scoring pass the paper's method avoids — run it
        // on the batched (norm-cached, pooled) scoring path; rows
        // already in the working set are skipped when collecting
        let d2s = model.dist2_batch(data);
        let mut violators: Vec<(f64, usize)> = Vec::new();
        let in_working: std::collections::HashSet<usize> = working.iter().copied().collect();
        for (i, &d2) in d2s.iter().enumerate() {
            if in_working.contains(&i) {
                continue;
            }
            if d2 > model.r2() + cfg.margin {
                violators.push((d2, i));
            }
        }
        if violators.is_empty() {
            converged = true;
            break;
        }
        violators.sort_by(|a, b| b.0.partial_cmp(&a.0).unwrap());
        for (_, i) in violators.into_iter().take(cfg.add_per_round) {
            working.push(i);
        }
        let ws = data.gather(&working);
        let (m, stats) = train_detailed(&ws, params, None)?;
        solver.absorb(&stats);
        solver_calls += 1;
        rows_touched += ws.rows();
        model = m;
    }

    Ok(LuoOutcome {
        model,
        rounds,
        scoring_passes: rounds,
        converged,
        solver_calls,
        rows_touched,
        solver,
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{banana::Banana, Generator};

    #[test]
    fn luo_close_to_full_on_banana() {
        let data = Banana::default().generate(2000, 8);
        let params = SvddParams::gaussian(0.35, 0.001);
        let full = crate::svdd::train(&data, &params).unwrap();
        let luo = train_luo(&data, &params, &LuoConfig::default()).unwrap();
        let rel = (luo.model.r2() - full.r2()).abs() / full.r2();
        assert!(rel < 0.05, "R^2 gap {rel}");
        assert!(luo.rounds >= 1);
        assert_eq!(luo.rounds, luo.scoring_passes);
        // telemetry: chunk solves + initial working-set solve + one per round
        let chunks = (0..data.rows()).step_by(LuoConfig::default().chunk).count();
        assert_eq!(luo.solver_calls, chunks + 1 + (luo.rounds - usize::from(luo.converged)));
        assert!(luo.rows_touched >= data.rows());
        assert!(luo.solver.smo_iterations > 0);
        assert!(luo.solver.gap.is_finite());
    }

    #[test]
    fn luo_covers_training_data() {
        let data = Banana::default().generate(1500, 9);
        let params = SvddParams::gaussian(0.35, 0.002);
        let luo = train_luo(&data, &params, &LuoConfig::default()).unwrap();
        let outside = (0..data.rows())
            .filter(|&i| luo.model.dist2(data.row(i)) > luo.model.r2() + 1e-6)
            .count();
        // converged combination leaves (almost) no violators
        assert!(outside * 50 < data.rows(), "{outside} violators remain");
    }
}
