//! The paper's "full SVDD method": train on every observation in one
//! solve. This is the Table-I / Fig-1 baseline.

use crate::error::Result;
use crate::svdd::model::SvddModel;
use crate::svdd::trainer::{train_detailed, SolverStats, SvddParams};
use crate::util::matrix::Matrix;
use crate::util::timer::Stopwatch;

/// Outcome with timing, for the bench harnesses.
#[derive(Clone, Debug)]
pub struct FullOutcome {
    pub model: SvddModel,
    pub seconds: f64,
    /// SMO telemetry of the one big solve (`fastsvdd train -v`).
    pub solver: SolverStats,
}

/// Train on all rows, timing the solve.
pub fn train_full(data: &Matrix, params: &SvddParams) -> Result<FullOutcome> {
    let sw = Stopwatch::start();
    let (model, solver) = train_detailed(data, params, None)?;
    Ok(FullOutcome { model, seconds: sw.elapsed_secs(), solver })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{banana::Banana, Generator};

    #[test]
    fn full_training_works_and_times() {
        let data = Banana::default().generate(800, 1);
        let out = train_full(&data, &SvddParams::gaussian(0.35, 0.005)).unwrap();
        assert!(out.seconds > 0.0);
        assert!(out.model.num_sv() >= 3);
    }
}
