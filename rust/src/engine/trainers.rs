//! The built-in [`Trainer`] implementations — one unit struct per
//! [`Method`], each a pure delegation to the method's pre-existing
//! entry point so seeded trajectories stay byte-for-byte identical to
//! the legacy calls (pinned by `tests/pipeline_integration.rs`).

use crate::baselines::{train_full, train_kim, train_luo};
use crate::config::Method;
use crate::distributed::tcp::train_tcp_cluster;
use crate::distributed::{train_local_cluster, DistributedConfig};
use crate::error::{Error, Result};
use crate::incremental::{reduce_and_train, IncrementalSvdd, InsertionOrder};
use crate::sampling::{SamplingTrainer, StreamingSvdd};
use crate::util::matrix::Matrix;
use crate::util::timer::fmt_duration;

use super::{TrainContext, TrainReport, Trainer};

/// [`Method::Full`]: one solve over all observations (Table I).
pub struct Full;

impl Trainer for Full {
    fn method(&self) -> Method {
        Method::Full
    }

    fn train(&self, ctx: &TrainContext<'_>, data: &Matrix) -> Result<TrainReport> {
        let out = train_full(data, &ctx.params)?;
        Ok(TrainReport {
            method: Method::Full,
            seconds: 0.0,
            iterations: 1,
            converged: true,
            solver_calls: 1,
            rows_touched: data.rows(),
            warm_start: false,
            sample_size: 0,
            solver: out.solver,
            trace: Vec::new(),
            extras: vec![("solve".into(), fmt_duration(out.seconds))],
            notes: Vec::new(),
            model: out.model,
        })
    }
}

/// [`Method::Sampling`]: the paper's Algorithm 1, including
/// multi-candidate iterations, `warm_alpha` carry, gram backends and
/// warm starts from a previous model.
pub struct Sampling;

impl Trainer for Sampling {
    fn method(&self) -> Method {
        Method::Sampling
    }

    fn train(&self, ctx: &TrainContext<'_>, data: &Matrix) -> Result<TrainReport> {
        let mut trainer = SamplingTrainer::new(ctx.params, ctx.sampling);
        if let Some(backend) = ctx.backend {
            trainer = trainer.with_backend(backend);
        }
        if let Some(pool) = ctx.pool {
            trainer = trainer.with_pool(pool);
        }
        let out = match ctx.warm_start {
            Some(prev) => trainer.train_warm(data, ctx.seed, prev)?,
            None => trainer.train(data, ctx.seed)?,
        };
        let mut notes = Vec::new();
        if ctx.sampling.candidates_per_iter > 1 {
            notes.push(format!(
                "candidates: {} per iteration (best-R^2 promotion)",
                ctx.sampling.candidates_per_iter
            ));
        }
        Ok(TrainReport {
            method: Method::Sampling,
            seconds: 0.0,
            iterations: out.iterations,
            converged: out.converged,
            solver_calls: out.solver_calls,
            rows_touched: out.rows_touched,
            warm_start: out.warm_start,
            sample_size: ctx.sampling.sample_size,
            solver: out.solver,
            trace: out.trace,
            extras: vec![
                ("iterations".into(), out.iterations.to_string()),
                ("converged".into(), out.converged.to_string()),
                ("rows_touched".into(), out.rows_touched.to_string()),
            ],
            notes,
            model: out.model,
        })
    }
}

/// [`Method::Distributed`]: shard → per-worker Algorithm 1 → SV-set
/// union → one combining solve (paper section III-1). In-process
/// workers by default; TCP workers when [`TrainContext::addrs`] is
/// non-empty.
pub struct Distributed;

impl Trainer for Distributed {
    fn method(&self) -> Method {
        Method::Distributed
    }

    fn train(&self, ctx: &TrainContext<'_>, data: &Matrix) -> Result<TrainReport> {
        let dcfg = DistributedConfig {
            workers: ctx.workers,
            sampling: ctx.sampling,
            seed: ctx.seed,
            shuffle_seed: ctx.shuffle_seed,
            combine: ctx.combine,
            max_retries: ctx.max_retries,
            worker_timeout: ctx.worker_timeout,
            min_workers: ctx.min_workers,
        };
        let out = if ctx.addrs.is_empty() {
            train_local_cluster(data, &ctx.params, &dcfg)?
        } else {
            train_tcp_cluster(data, &ctx.params, &dcfg, &ctx.addrs)?
        };
        if let Some(metrics) = ctx.metrics {
            metrics.shard_retries.add(out.retry.shard_retries);
            metrics.shards_reassigned.add(out.retry.shards_reassigned);
            metrics.worker_failures.add(out.retry.worker_failures);
            metrics.workers_lost.add(out.retry.workers_lost);
            metrics.shards_local_fallback.add(out.retry.shards_local_fallback);
        }
        let notes = out
            .reports
            .iter()
            .map(|r| {
                format!(
                    "worker {}: shard={} svs={} iters={} converged={}",
                    r.worker, r.shard_rows, r.sv_count, r.iterations, r.converged
                )
            })
            .collect();
        let mut extras = vec![
            ("union_rows".into(), out.union_rows.to_string()),
            ("combine".into(), dcfg.combine.to_string()),
            ("combine_solves".into(), out.combine_solves.to_string()),
        ];
        if out.retry != crate::distributed::RetryStats::default() {
            extras.push(("shard_retries".into(), out.retry.shard_retries.to_string()));
            extras.push(("workers_lost".into(), out.retry.workers_lost.to_string()));
            extras.push((
                "shards_local_fallback".into(),
                out.retry.shards_local_fallback.to_string(),
            ));
        }
        Ok(TrainReport {
            method: Method::Distributed,
            seconds: 0.0,
            iterations: out.reports.iter().map(|r| r.iterations).sum(),
            converged: out.reports.iter().all(|r| r.converged),
            solver_calls: out.combine_solves,
            rows_touched: out.union_rows,
            warm_start: false,
            sample_size: ctx.sampling.sample_size,
            solver: out.solver,
            trace: Vec::new(),
            extras,
            notes,
            model: out.model,
        })
    }
}

/// [`Method::Luo`]: decomposition + combination with a full-data
/// scoring pass per round (the structural cost the paper removes).
pub struct Luo;

impl Trainer for Luo {
    fn method(&self) -> Method {
        Method::Luo
    }

    fn train(&self, ctx: &TrainContext<'_>, data: &Matrix) -> Result<TrainReport> {
        let out = train_luo(data, &ctx.params, &ctx.luo)?;
        Ok(TrainReport {
            method: Method::Luo,
            seconds: 0.0,
            iterations: out.rounds,
            converged: out.converged,
            solver_calls: out.solver_calls,
            rows_touched: out.rows_touched,
            warm_start: false,
            sample_size: 0,
            solver: out.solver,
            trace: Vec::new(),
            extras: vec![
                ("rounds".into(), out.rounds.to_string()),
                ("scoring_passes".into(), out.scoring_passes.to_string()),
            ],
            notes: Vec::new(),
            model: out.model,
        })
    }
}

/// [`Method::Kim`]: k-means divide-and-conquer (every observation
/// participates).
pub struct Kim;

impl Trainer for Kim {
    fn method(&self) -> Method {
        Method::Kim
    }

    fn train(&self, ctx: &TrainContext<'_>, data: &Matrix) -> Result<TrainReport> {
        let out = train_kim(data, &ctx.params, &ctx.kim)?;
        Ok(TrainReport {
            method: Method::Kim,
            seconds: 0.0,
            iterations: 1,
            converged: true,
            solver_calls: out.solver_calls,
            rows_touched: out.rows_touched,
            warm_start: false,
            sample_size: 0,
            solver: out.solver,
            trace: Vec::new(),
            extras: vec![("pooled_svs".into(), out.pooled_svs.to_string())],
            notes: Vec::new(),
            model: out.model,
        })
    }
}

/// [`Method::Streaming`]: feed the data through [`StreamingSvdd`]
/// window by window and snapshot the final master-set model — the
/// batch spelling of the online maintainer, so the engine can compare
/// it against the other methods on equal footing.
pub struct Streaming;

impl Trainer for Streaming {
    fn method(&self) -> Method {
        Method::Streaming
    }

    fn train(&self, ctx: &TrainContext<'_>, data: &Matrix) -> Result<TrainReport> {
        let mut cfg = ctx.streaming;
        // clamp so small data sets still complete at least one window
        cfg.window = cfg.window.min(data.rows()).max(1);
        let mut stream = StreamingSvdd::new(ctx.params, cfg, ctx.seed);
        stream.push_batch(data)?;
        let model = match stream.model() {
            Some(m) => m.clone(),
            None => {
                return Err(Error::invalid(format!(
                    "streaming snapshot needs a full window ({} rows, got {})",
                    cfg.window,
                    data.rows()
                )))
            }
        };
        // the tail that never filled a window was not trained on
        let dropped = stream.buffered();
        Ok(TrainReport {
            method: Method::Streaming,
            seconds: 0.0,
            iterations: stream.updates(),
            converged: true,
            solver_calls: stream.solver_calls(),
            rows_touched: data.rows() - dropped,
            warm_start: false,
            sample_size: cfg.sample_size,
            solver: *stream.solver_stats(),
            trace: Vec::new(),
            extras: vec![
                ("updates".into(), stream.updates().to_string()),
                ("window".into(), cfg.window.to_string()),
                ("dropped_rows".into(), dropped.to_string()),
            ],
            notes: Vec::new(),
            model,
        })
    }
}

/// [`Method::Incremental`]: seed the exact online state machine
/// ([`IncrementalSvdd`]) from the first rows, then feed the rest one
/// `add_point` at a time — the batch spelling of per-event online
/// learning, so the engine can compare it against the other methods.
/// When the active set exceeds [`crate::incremental::IncrementalConfig::max_points`]
/// the oldest point is evicted FIFO, bounding the maintained Gram.
pub struct Incremental;

impl Trainer for Incremental {
    fn method(&self) -> Method {
        Method::Incremental
    }

    fn train(&self, ctx: &TrainContext<'_>, data: &Matrix) -> Result<TrainReport> {
        if data.rows() == 0 {
            return Err(Error::invalid("incremental: empty training set"));
        }
        let cfg = ctx.incremental;
        let seed_n = data.rows().min(64);
        let seed_rows: Vec<usize> = (0..seed_n).collect();
        let mut inc = IncrementalSvdd::with_data(ctx.params, cfg, &data.gather(&seed_rows))?;
        let mut order = InsertionOrder::new();
        for i in 0..seed_n {
            order.record_add(i);
        }
        for i in seed_n..data.rows() {
            inc.add_point(data.row(i))?;
            order.record_add(inc.len() - 1);
            if cfg.max_points > 0 && inc.len() > cfg.max_points {
                let oldest = order.oldest().expect("non-empty ledger");
                let last = inc.len() - 1;
                inc.remove_point(oldest)?;
                order.record_swap_remove(oldest, last);
            }
        }
        let model = inc.model()?;
        Ok(TrainReport {
            method: Method::Incremental,
            seconds: 0.0,
            iterations: inc.updates() as usize,
            converged: inc.gap() <= ctx.params.smo.tol,
            solver_calls: inc.resyncs() as usize,
            rows_touched: data.rows(),
            warm_start: false,
            sample_size: seed_n,
            solver: *inc.solver_stats(),
            trace: Vec::new(),
            extras: vec![
                ("updates".into(), inc.updates().to_string()),
                ("resyncs".into(), inc.resyncs().to_string()),
                ("migrations".into(), inc.migrations().to_string()),
                ("active".into(), inc.len().to_string()),
                ("gap".into(), format!("{:.3e}", inc.gap())),
            ],
            notes: Vec::new(),
            model,
        })
    }
}

/// [`Method::Reduction`]: boundary-preserving sample reduction — a
/// pilot model ranks every row by distance to the decision boundary,
/// only the nearest `target` rows reach the final solver.
pub struct Reduction;

impl Trainer for Reduction {
    fn method(&self) -> Method {
        Method::Reduction
    }

    fn train(&self, ctx: &TrainContext<'_>, data: &Matrix) -> Result<TrainReport> {
        let (model, solver, out) = reduce_and_train(data, &ctx.params, &ctx.reduction, ctx.seed)?;
        let solver_calls = if out.pilot_size > 0 { 2 } else { 1 };
        Ok(TrainReport {
            method: Method::Reduction,
            seconds: 0.0,
            iterations: 1,
            converged: true,
            solver_calls,
            rows_touched: out.pilot_size + out.kept.len(),
            warm_start: false,
            sample_size: out.kept.len(),
            solver,
            trace: Vec::new(),
            extras: vec![
                ("kept".into(), out.kept.len().to_string()),
                ("pilot".into(), out.pilot_size.to_string()),
                ("shell".into(), format!("{:.3e}", out.shell_width)),
            ],
            notes: Vec::new(),
            model,
        })
    }
}
