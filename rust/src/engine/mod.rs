//! Unified training engine: one [`Trainer`] trait + [`Engine`] facade
//! over every training method.
//!
//! The paper's core claim is that iterative sampling is *one of
//! several interchangeable ways* to obtain a data description. This
//! module makes that interchangeability literal: every method —
//! [`Method::Full`], [`Method::Sampling`] (including
//! `candidates_per_iter` and `warm_alpha`), [`Method::Distributed`],
//! [`Method::Luo`], [`Method::Kim`], the streaming snapshot
//! [`Method::Streaming`], the online state machine
//! [`Method::Incremental`] and the boundary-preserving
//! [`Method::Reduction`] — implements the same [`Trainer`] trait,
//! consumes the same [`TrainContext`] and produces the same
//! [`TrainReport`], so the launcher, the lifecycle driver, grid
//! search, the bench harnesses and the distributed controller run all
//! of them through one code path.
//!
//! - [`TrainContext`] carries everything a trainer may need besides
//!   the data: kernel/solver parameters, the Algorithm-1 sampling
//!   knobs, the RNG seed, an optional explicit [`Pool`], an optional
//!   [`GramBackend`] for the small sample/union solves, an optional
//!   warm-start model, a [`Metrics`] sink, and the per-method configs
//!   (Luo, Kim, distributed, streaming). Trainers read the fields they
//!   understand and ignore the rest, so one context drives any method.
//! - [`TrainReport`] carries the model plus the unified telemetry:
//!   wall time, outer iterations, convergence, SMO solve count,
//!   rows touched, aggregated [`SolverStats`], the Fig-7 trace, and
//!   method-specific extras as ordered key/value pairs.
//! - [`trainer_for`] is the `Method`-keyed registry — the single
//!   `match` over methods in the whole crate. Adding a trainer is a
//!   one-file change: implement [`Trainer`], register it here.
//! - [`Engine`] is the config-driven facade:
//!   `Engine::from_config(&cfg)?.train(&data)?`.
//!
//! Seeded trajectories are untouched: each built-in trainer delegates
//! to the pre-existing entry point (`SamplingTrainer`, `train_full`,
//! `train_luo`, `train_kim`, `train_local_cluster`, `StreamingSvdd`),
//! so `Engine` output is byte-identical to the legacy call — pinned
//! per method by `tests/pipeline_integration.rs`, including the
//! `--wss legacy` golden path and the K=1 sampling stream.

pub mod trainers;

use std::net::SocketAddr;

use crate::baselines::{KimConfig, LuoConfig};
use crate::config::{Method, RunConfig};
use crate::distributed::{CombineMode, DistributedConfig};
use crate::error::Result;
use crate::incremental::{IncrementalConfig, ReductionConfig};
use crate::metrics::Metrics;
use crate::parallel::Pool;
use crate::sampling::{GramBackend, SamplingConfig, StreamingConfig, TracePoint};
use crate::svdd::model::SvddModel;
use crate::svdd::trainer::{SolverStats, SvddParams};
use crate::util::matrix::Matrix;
use crate::util::timer::Stopwatch;

/// Everything a [`Trainer`] may need besides the data. One context
/// drives any method: trainers read the fields they understand and
/// ignore the rest (e.g. only the sampling trainer consults
/// [`TrainContext::backend`]; only the distributed trainer consults
/// [`TrainContext::workers`]).
#[derive(Clone)]
pub struct TrainContext<'a> {
    /// Kernel + SMO parameters shared by every method.
    pub params: SvddParams,
    /// Algorithm-1 knobs (sample size, tolerances, candidates,
    /// `warm_alpha`, trace recording). The distributed trainer hands
    /// these to its workers; the streaming trainer samples per window.
    pub sampling: SamplingConfig,
    /// RNG seed for the run.
    pub seed: u64,
    /// Explicit pool for candidate solves (`None` = the global pool).
    pub pool: Option<Pool>,
    /// Gram backend for the small sample/union solves (XLA artifact or
    /// [`crate::parallel::PooledGram`]); `None` = the lazy native path.
    pub backend: Option<&'a dyn GramBackend>,
    /// Warm-start model: the sampling trainer seeds `SV*` from its
    /// support vectors ([`crate::sampling::SamplingTrainer::train_warm`]).
    pub warm_start: Option<&'a SvddModel>,
    /// Metrics sink: [`run`] records every report's uniform telemetry
    /// here ([`Metrics::record_training`]).
    pub metrics: Option<&'a Metrics>,
    /// Luo et al. baseline knobs.
    pub luo: LuoConfig,
    /// Kim et al. baseline knobs. Note `KimConfig::seed` is its own
    /// field (historically fixed, independent of [`TrainContext::seed`])
    /// so seeded legacy runs stay byte-for-byte reproducible.
    pub kim: KimConfig,
    /// Distributed worker count `p`.
    pub workers: usize,
    /// Seeded pre-shuffle before distributed sharding.
    pub shuffle_seed: Option<u64>,
    /// Distributed SV-set combine strategy (flat or tree).
    pub combine: CombineMode,
    /// Distributed: extra attempts a failed shard is granted.
    pub max_retries: usize,
    /// Distributed: per-attempt socket deadline (connect/read/write and
    /// heartbeat probes).
    pub worker_timeout: std::time::Duration,
    /// Distributed: degrade to in-controller training when fewer than
    /// this many TCP workers remain alive.
    pub min_workers: usize,
    /// TCP worker addresses; empty = in-process local cluster.
    pub addrs: Vec<SocketAddr>,
    /// Streaming-snapshot knobs (window, drift monitor, per-point
    /// incremental mode).
    pub streaming: StreamingConfig,
    /// Online-update knobs (staleness budget, divergence tolerance,
    /// active-set cap) for [`Method::Incremental`].
    pub incremental: IncrementalConfig,
    /// Boundary-preserving reduction knobs for [`Method::Reduction`].
    pub reduction: ReductionConfig,
}

impl TrainContext<'static> {
    /// A context with library defaults for everything but the three
    /// universal inputs.
    pub fn new(params: SvddParams, sampling: SamplingConfig, seed: u64) -> TrainContext<'static> {
        let dist = DistributedConfig::default();
        TrainContext {
            params,
            sampling,
            seed,
            pool: None,
            backend: None,
            warm_start: None,
            metrics: None,
            luo: LuoConfig::default(),
            kim: KimConfig::default(),
            workers: 4,
            shuffle_seed: None,
            combine: dist.combine,
            max_retries: dist.max_retries,
            worker_timeout: dist.worker_timeout,
            min_workers: dist.min_workers,
            addrs: Vec::new(),
            streaming: StreamingConfig { sample_size: sampling.sample_size, ..Default::default() },
            incremental: IncrementalConfig::default(),
            reduction: ReductionConfig::default(),
        }
    }

    /// The context a [`RunConfig`] describes (what `Engine::train`
    /// uses). Method-specific configs without `RunConfig` keys (Luo,
    /// Kim, streaming window) keep their historical defaults.
    pub fn from_config(cfg: &RunConfig) -> TrainContext<'static> {
        let mut ctx = TrainContext::new(cfg.params(), cfg.sampling(), cfg.seed);
        ctx.workers = cfg.workers;
        ctx.shuffle_seed = cfg.shuffle_seed;
        ctx.combine = cfg.combine;
        ctx.max_retries = cfg.max_retries;
        ctx.worker_timeout = std::time::Duration::from_millis(cfg.worker_timeout_ms);
        ctx.min_workers = cfg.min_workers;
        ctx.streaming.incremental = cfg.stream_incremental;
        ctx.streaming.stale_budget = cfg.stale_budget;
        ctx.incremental = cfg.incremental();
        ctx.reduction = cfg.reduction();
        ctx
    }
}

impl<'a> TrainContext<'a> {
    /// Route sample/union gram computations through a backend.
    pub fn with_backend(mut self, backend: &'a dyn GramBackend) -> TrainContext<'a> {
        self.backend = Some(backend);
        self
    }

    /// Seed the run from a previously trained model.
    pub fn with_warm_start(mut self, model: &'a SvddModel) -> TrainContext<'a> {
        self.warm_start = Some(model);
        self
    }

    /// Record the run's telemetry into a metrics registry.
    pub fn with_metrics(mut self, metrics: &'a Metrics) -> TrainContext<'a> {
        self.metrics = Some(metrics);
        self
    }

    /// Solve candidates on an explicit pool instead of the global one.
    pub fn with_pool(mut self, pool: Pool) -> TrainContext<'a> {
        self.pool = Some(pool);
        self
    }
}

/// What any training method produces: the model plus uniform
/// telemetry, so every consumer (CLI `-v` block, registry metadata,
/// metrics, bench tables) treats all methods identically.
#[derive(Clone, Debug)]
pub struct TrainReport {
    /// Which method produced this report.
    pub method: Method,
    pub model: SvddModel,
    /// Wall time of the whole train call (stamped by [`run`]).
    pub seconds: f64,
    /// Outer iterations of the method: Algorithm-1 iterations,
    /// Luo combination rounds, streaming window updates, worker
    /// iteration total (distributed), 1 for one-shot methods.
    pub iterations: usize,
    /// Whether the method's own stopping criterion fired (one-shot
    /// methods report `true`).
    pub converged: bool,
    /// SMO solves issued. For the distributed method this counts the
    /// controller's combining solve only — worker solves stay remote.
    pub solver_calls: usize,
    /// Observations fed to solvers (the "fraction of the data the
    /// method ever looks at").
    pub rows_touched: usize,
    /// Whether the run was seeded from a previous model.
    pub warm_start: bool,
    /// Algorithm-1 sample size `n` (0 when not sample-trained) — feeds
    /// [`crate::registry::VersionMeta`].
    pub sample_size: usize,
    /// Aggregated SMO telemetry across every solve of the run.
    pub solver: SolverStats,
    /// Per-iteration trace (Fig 7) when the method records one.
    pub trace: Vec<TracePoint>,
    /// Method-specific extras as ordered `key=value` pairs (e.g.
    /// `rounds` for Luo, `pooled_svs` for Kim, `union_rows` for
    /// distributed).
    pub extras: Vec<(String, String)>,
    /// Free-form progress lines (per-worker reports, candidate mode),
    /// printed indented by the CLI.
    pub notes: Vec<String>,
}

impl TrainReport {
    /// The extras rendered as a `k1=v1 k2=v2` line for log output.
    pub fn extras_line(&self) -> String {
        self.extras
            .iter()
            .map(|(k, v)| format!("{k}={v}"))
            .collect::<Vec<_>>()
            .join(" ")
    }

    /// Record the uniform telemetry into a metrics registry.
    pub fn record_to(&self, metrics: &Metrics) {
        metrics.record_training(self.solver_calls, self.iterations, &self.solver);
    }
}

/// A training method. Implementations are pure delegations to the
/// method's algorithm; cross-cutting concerns (timing, metrics) live
/// in [`run`].
pub trait Trainer: Send + Sync {
    /// The [`Method`] this trainer serves.
    fn method(&self) -> Method;

    /// Train a model on `data` under `ctx`.
    fn train(&self, ctx: &TrainContext<'_>, data: &Matrix) -> Result<TrainReport>;
}

/// The `Method`-keyed trainer registry — the single per-method
/// dispatch in the crate. To add a method: add a [`Method`] variant,
/// implement [`Trainer`] (usually in [`trainers`]), register it here;
/// every consumer (CLI, lifecycle, benches, grid search) picks it up
/// without changes.
pub fn trainer_for(method: Method) -> Box<dyn Trainer> {
    match method {
        Method::Sampling => Box::new(trainers::Sampling),
        Method::Full => Box::new(trainers::Full),
        Method::Distributed => Box::new(trainers::Distributed),
        Method::Luo => Box::new(trainers::Luo),
        Method::Kim => Box::new(trainers::Kim),
        Method::Streaming => Box::new(trainers::Streaming),
        Method::Incremental => Box::new(trainers::Incremental),
        Method::Reduction => Box::new(trainers::Reduction),
    }
}

/// Run a trainer: train, stamp the wall time, and record the report
/// into `ctx.metrics` (when attached). [`Engine::train`] and the
/// lifecycle driver both go through here so telemetry is recorded
/// exactly once per run.
pub fn run(trainer: &dyn Trainer, ctx: &TrainContext<'_>, data: &Matrix) -> Result<TrainReport> {
    let mut span = crate::obs::Span::enter("engine.train");
    let sw = Stopwatch::start();
    let mut report = trainer.train(ctx, data)?;
    report.seconds = sw.elapsed_secs();
    if let Some(metrics) = ctx.metrics {
        report.record_to(metrics);
    }
    if span.is_live() {
        span.str("method", report.method.to_string());
        span.u64("iterations", report.iterations as u64);
        span.f64("r2", report.model.r2());
        span.u64("converged", report.converged as u64);
        drop(span);
        crate::obs::emit(
            "train.report",
            vec![
                ("method", crate::obs::Value::Str(report.method.to_string())),
                ("seconds", crate::obs::Value::F64(report.seconds)),
                ("iterations", crate::obs::Value::U64(report.iterations as u64)),
                ("r2", crate::obs::Value::F64(report.model.r2())),
                ("rows_touched", crate::obs::Value::U64(report.rows_touched as u64)),
            ],
        );
    }
    Ok(report)
}

/// Config-driven facade: `Engine::from_config(&cfg)?.train(&data)?`
/// trains with whatever method the config names.
pub struct Engine {
    cfg: RunConfig,
    trainer: Box<dyn Trainer>,
}

impl Engine {
    /// Validate the config, install its parallelism (the process-global
    /// thread count — `RunConfig.threads` is honored whether training
    /// starts from the CLI or from library code; last install wins) and
    /// look up its method's trainer.
    pub fn from_config(cfg: &RunConfig) -> Result<Engine> {
        cfg.validate()?;
        crate::parallel::install(cfg.parallelism());
        Ok(Engine { cfg: cfg.clone(), trainer: trainer_for(cfg.method) })
    }

    pub fn method(&self) -> Method {
        self.cfg.method
    }

    pub fn trainer(&self) -> &dyn Trainer {
        self.trainer.as_ref()
    }

    /// The context [`Engine::train`] uses — take it, customize
    /// (backend, warm start, metrics, trace recording), and pass to
    /// [`Engine::train_with`].
    pub fn context(&self) -> TrainContext<'static> {
        TrainContext::from_config(&self.cfg)
    }

    /// Train on `data` with the config's own context.
    pub fn train(&self, data: &Matrix) -> Result<TrainReport> {
        self.train_with(&self.context(), data)
    }

    /// Train on `data` with a customized context.
    pub fn train_with(&self, ctx: &TrainContext<'_>, data: &Matrix) -> Result<TrainReport> {
        run(self.trainer.as_ref(), ctx, data)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{banana::Banana, Generator};

    fn small_cfg(method: Method) -> RunConfig {
        RunConfig {
            rows: 600,
            method,
            sample_size: 6,
            ..RunConfig::default()
        }
    }

    #[test]
    fn registry_covers_every_method() {
        for m in Method::ALL {
            assert_eq!(trainer_for(m).method(), m, "registry mismatch for {m}");
        }
    }

    #[test]
    fn engine_rejects_invalid_config() {
        let cfg = RunConfig { bandwidth: -1.0, ..RunConfig::default() };
        assert!(Engine::from_config(&cfg).is_err());
    }

    #[test]
    fn engine_trains_sampling_and_reports() {
        let cfg = small_cfg(Method::Sampling);
        let data = Banana::default().generate(cfg.rows, cfg.seed);
        let engine = Engine::from_config(&cfg).unwrap();
        assert_eq!(engine.method(), Method::Sampling);
        let report = engine.train(&data).unwrap();
        assert_eq!(report.method, Method::Sampling);
        assert!(report.model.r2() > 0.0);
        assert!(report.seconds > 0.0);
        assert!(report.iterations >= 1);
        assert!(report.solver_calls >= 1);
        assert_eq!(report.sample_size, cfg.sample_size);
        assert!(report.solver.smo_iterations > 0);
        let line = report.extras_line();
        assert!(line.contains("iterations="), "extras line: {line}");
    }

    #[test]
    fn metrics_sink_records_for_every_local_method() {
        let data = Banana::default().generate(400, 3);
        for method in [Method::Full, Method::Sampling, Method::Luo, Method::Kim, Method::Reduction]
        {
            let cfg = small_cfg(method);
            let engine = Engine::from_config(&cfg).unwrap();
            let metrics = Metrics::new();
            let ctx = engine.context().with_metrics(&metrics);
            let report = engine.train_with(&ctx, &data).unwrap();
            assert!(report.model.num_sv() >= 1, "{method}: no SVs");
            assert_eq!(
                metrics.solver_calls.get(),
                report.solver_calls as u64,
                "{method}: solver_calls not recorded"
            );
            assert!(metrics.smo_iterations.get() > 0, "{method}: smo telemetry missing");
        }
    }

    #[test]
    fn streaming_snapshot_trains_and_counts_windows() {
        let cfg = small_cfg(Method::Streaming);
        let data = Banana::default().generate(600, 5);
        let engine = Engine::from_config(&cfg).unwrap();
        let report = engine.train(&data).unwrap();
        // default window 256: 2 full windows, 88 rows left in buffer
        assert_eq!(report.iterations, 2);
        assert_eq!(report.rows_touched, 512);
        assert_eq!(report.solver_calls, 4);
        assert!(report.solver.smo_iterations > 0);
        assert!(report.extras_line().contains("window=256"));
    }

    #[test]
    fn streaming_snapshot_clamps_window_to_small_data() {
        let cfg = small_cfg(Method::Streaming);
        let data = Banana::default().generate(40, 6);
        let report = Engine::from_config(&cfg).unwrap().train(&data).unwrap();
        assert_eq!(report.iterations, 1);
        assert_eq!(report.rows_touched, 40);
    }

    #[test]
    fn engine_trains_incremental_and_reports_updates() {
        let cfg = RunConfig { rows: 200, method: Method::Incremental, ..RunConfig::default() };
        let data = Banana::default().generate(cfg.rows, cfg.seed);
        let report = Engine::from_config(&cfg).unwrap().train(&data).unwrap();
        assert_eq!(report.method, Method::Incremental);
        // 64 seeded + 136 per-point adds
        assert_eq!(report.iterations, 136);
        assert_eq!(report.sample_size, 64);
        assert!(report.solver_calls >= 1, "at least the seed resync");
        assert!(report.model.r2() > 0.0);
        assert!(report.extras_line().contains("resyncs="));
    }

    #[test]
    fn engine_incremental_caps_active_set() {
        let cfg = RunConfig {
            rows: 300,
            method: Method::Incremental,
            // stale_budget flows into IncrementalConfig via cfg.incremental()
            stale_budget: 32,
            ..RunConfig::default()
        };
        let data = Banana::default().generate(cfg.rows, cfg.seed);
        let engine = Engine::from_config(&cfg).unwrap();
        let mut ctx = engine.context();
        ctx.incremental.max_points = 128;
        let report = engine.train_with(&ctx, &data).unwrap();
        // adds past the cap evict FIFO: active set pinned at max_points
        let line = report.extras_line();
        assert!(line.contains("active=128"), "extras: {line}");
        // 236 adds + 172 evictions
        assert_eq!(report.iterations, 236 + (300 - 128));
        assert!(report.solver_calls >= 2, "staleness budget must trip");
    }

    #[test]
    fn engine_trains_reduction_and_reports_kept_rows() {
        let cfg = RunConfig {
            rows: 500,
            method: Method::Reduction,
            reduction_target: 100,
            ..RunConfig::default()
        };
        let data = Banana::default().generate(cfg.rows, cfg.seed);
        let report = Engine::from_config(&cfg).unwrap().train(&data).unwrap();
        assert_eq!(report.method, Method::Reduction);
        assert_eq!(report.sample_size, 100);
        assert_eq!(report.solver_calls, 2);
        assert!(report.model.r2() > 0.0);
        assert!(report.extras_line().contains("kept=100"));
    }

    #[test]
    fn warm_start_flows_through_context() {
        let cfg = small_cfg(Method::Sampling);
        let data = Banana::default().generate(cfg.rows, 7);
        let engine = Engine::from_config(&cfg).unwrap();
        let cold = engine.train(&data).unwrap();
        assert!(!cold.warm_start);
        let ctx = engine.context().with_warm_start(&cold.model);
        let warm = engine.train_with(&ctx, &data).unwrap();
        assert!(warm.warm_start);
    }
}
