//! Parallel execution subsystem: a dependency-free chunked thread pool
//! the hot paths share.
//!
//! The paper's structure makes its dominant costs embarrassingly
//! parallel — every iteration's Gram computation, every SMO kernel
//! column, every scoring batch is a set of independent per-index
//! evaluations. This module turns that independence into wall-clock
//! speed without giving up the repo's reproducibility contract:
//!
//! - **Chunked, deterministically ordered.** Work is split into
//!   fixed-size chunks of the output buffer; each chunk's destination
//!   slice is determined by its index alone, so results land in the
//!   same place no matter which worker computes them. Every per-index
//!   computation the pool runs is a pure function of the index, which
//!   makes parallel output **bit-identical** to the serial path at any
//!   thread count (asserted by `tests/parallel_determinism.rs`).
//! - **Scoped workers.** [`Pool::run_chunks`] spawns workers with
//!   [`std::thread::scope`], so closures may borrow the data matrix and
//!   model directly — no `Arc` wrapping, no `'static` bounds, no
//!   third-party crate. Worker panics propagate to the caller.
//! - **Cost-gated.** [`Pool::for_work`] falls back to the serial path
//!   below [`MIN_PAR_WORK`] scalar operations, so the small
//!   Algorithm-1 sample/union solves never pay thread-spawn overhead.
//!
//! The active degree of parallelism is process-global
//! ([`install`] / [`global`]), configured from `--threads auto|N` or
//! the `threads` config key, so every layer — trainer, SMO solver,
//! batcher, score server — draws from one knob.

use std::sync::atomic::{AtomicUsize, Ordering};

use crate::error::{Error, Result};
use crate::svdd::kernel::Kernel;
use crate::util::matrix::Matrix;

/// Below this many scalar operations a parallel region runs serially —
/// scoped-thread spawn costs tens of microseconds, which dominates tiny
/// workloads like the Algorithm-1 union solves (~40 rows x few dims).
pub const MIN_PAR_WORK: usize = 1 << 16;

/// Requested degree of parallelism: `auto` (all available cores) or a
/// fixed thread count.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Default)]
pub enum ThreadCount {
    /// Use `std::thread::available_parallelism()`.
    #[default]
    Auto,
    /// Exactly this many worker threads (>= 1).
    Fixed(usize),
}

impl ThreadCount {
    /// Parse `"auto"` or a positive integer.
    pub fn parse(s: &str) -> Result<ThreadCount> {
        if s == "auto" {
            return Ok(ThreadCount::Auto);
        }
        match s.parse::<usize>() {
            Ok(n) if n >= 1 => Ok(ThreadCount::Fixed(n)),
            _ => Err(Error::Config(format!(
                "threads must be 'auto' or a positive integer, got '{s}'"
            ))),
        }
    }

    /// Resolve to a concrete thread count.
    pub fn resolve(self) -> usize {
        match self {
            ThreadCount::Auto => available_cores(),
            ThreadCount::Fixed(n) => n.max(1),
        }
    }
}

impl std::fmt::Display for ThreadCount {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            ThreadCount::Auto => write!(f, "auto"),
            ThreadCount::Fixed(n) => write!(f, "{n}"),
        }
    }
}

/// Process-wide parallelism settings (the `config/` face of this
/// module; `RunConfig` carries one and the CLI `--threads` flag maps
/// onto it).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ParallelismConfig {
    pub threads: ThreadCount,
}

fn available_cores() -> usize {
    std::thread::available_parallelism()
        .map(|n| n.get())
        .unwrap_or(1)
}

/// Global thread-count override: 0 = auto (resolve at use), else fixed.
static GLOBAL_THREADS: AtomicUsize = AtomicUsize::new(0);

thread_local! {
    /// True on threads spawned by a pool region. Nested code that asks
    /// for the [`global`] pool from inside a worker (e.g. a candidate
    /// solve calling into the Gram path) gets the serial pool instead,
    /// so fan-outs never multiply into `K x cores` oversubscription.
    /// Explicit pools ([`Pool::new`], `with_pool`) are never demoted.
    static IN_POOL_WORKER: std::cell::Cell<bool> = const { std::cell::Cell::new(false) };
}

/// Install the process-global parallelism config (idempotent; cheap).
pub fn install(cfg: ParallelismConfig) {
    let t = match cfg.threads {
        ThreadCount::Auto => 0,
        ThreadCount::Fixed(n) => n.max(1),
    };
    GLOBAL_THREADS.store(t, Ordering::Relaxed);
}

/// The pool every hot path uses unless handed an explicit override.
/// Inside a pool worker this is the serial pool (see `IN_POOL_WORKER`),
/// so nested parallel regions don't oversubscribe the machine.
pub fn global() -> Pool {
    if IN_POOL_WORKER.with(|c| c.get()) {
        return Pool::serial();
    }
    match GLOBAL_THREADS.load(Ordering::Relaxed) {
        0 => Pool::auto(),
        t => Pool::new(t),
    }
}

/// A chunked scoped-thread pool. `Pool` is a lightweight handle (just a
/// degree of parallelism); workers are scoped to each call, so there is
/// no shutdown protocol and borrowed data flows straight into workers.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct Pool {
    threads: usize,
}

impl Pool {
    /// Pool with exactly `threads` workers (clamped to >= 1).
    pub fn new(threads: usize) -> Pool {
        Pool { threads: threads.max(1) }
    }

    /// Pool sized to the machine.
    pub fn auto() -> Pool {
        Pool::new(available_cores())
    }

    /// Single-threaded pool (the serial reference path).
    pub fn serial() -> Pool {
        Pool { threads: 1 }
    }

    pub fn threads(&self) -> usize {
        self.threads
    }

    /// This pool if `work_ops` (estimated scalar operations) is worth
    /// parallelizing, else the serial pool.
    pub fn for_work(self, work_ops: usize) -> Pool {
        if work_ops < MIN_PAR_WORK {
            Pool::serial()
        } else {
            self
        }
    }

    /// Run `f(chunk_start, chunk)` over `out` split into consecutive
    /// chunks of `chunk_len` (the final chunk may be shorter).
    ///
    /// Chunks are assigned to workers in contiguous blocks, but the
    /// `(chunk_start, chunk)` pairs handed to `f` are exactly the same
    /// set the serial path produces, and each output element belongs to
    /// exactly one chunk — so any `f` that writes `chunk[i]` as a pure
    /// function of `chunk_start + i` yields bit-identical output at
    /// every thread count.
    pub fn run_chunks<T, F>(&self, out: &mut [T], chunk_len: usize, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
    {
        let len = out.len();
        if len == 0 {
            return;
        }
        let chunk_len = chunk_len.max(1);
        let n_chunks = (len + chunk_len - 1) / chunk_len;
        let workers = self.threads.min(n_chunks);
        if workers <= 1 {
            for (ci, chunk) in out.chunks_mut(chunk_len).enumerate() {
                f(ci * chunk_len, chunk);
            }
            return;
        }
        std::thread::scope(|scope| {
            let f = &f;
            let mut rest = out;
            let mut consumed = 0usize; // elements handed to workers so far
            for w in 0..workers {
                // worker w owns chunks [n_chunks*w/workers, n_chunks*(w+1)/workers)
                let chunk_end = n_chunks * (w + 1) / workers;
                let end_el = (chunk_end * chunk_len).min(len);
                let (mine, tail) = std::mem::take(&mut rest).split_at_mut(end_el - consumed);
                rest = tail;
                let base = consumed;
                consumed = end_el;
                scope.spawn(move || {
                    IN_POOL_WORKER.with(|c| c.set(true));
                    for (ci, chunk) in mine.chunks_mut(chunk_len).enumerate() {
                        f(base + ci * chunk_len, chunk);
                    }
                });
            }
        });
    }

    /// Like [`Pool::run_chunks`], but worker block boundaries equalize
    /// cumulative per-chunk `weight` instead of chunk count. The chunk
    /// set and every chunk's destination slice are unchanged — only
    /// which worker runs which block differs — so output is identical
    /// to [`Pool::run_chunks`] for the same `f`. Use when chunk costs
    /// are systematically skewed (e.g. triangular Gram rows).
    pub fn run_chunks_weighted<T, F, W>(&self, out: &mut [T], chunk_len: usize, weight: W, f: F)
    where
        T: Send,
        F: Fn(usize, &mut [T]) + Sync,
        W: Fn(usize) -> usize,
    {
        let len = out.len();
        if len == 0 {
            return;
        }
        let chunk_len = chunk_len.max(1);
        let n_chunks = (len + chunk_len - 1) / chunk_len;
        let workers = self.threads.min(n_chunks);
        if workers <= 1 {
            for (ci, chunk) in out.chunks_mut(chunk_len).enumerate() {
                f(ci * chunk_len, chunk);
            }
            return;
        }
        // close block b after the first chunk where cumulative weight
        // reaches b/workers of the total (weights of 0 are fine)
        let total: usize = (0..n_chunks).map(&weight).sum();
        let mut bounds = Vec::with_capacity(workers + 1);
        bounds.push(0usize);
        let mut acc = 0usize;
        let mut next = 1usize;
        for ci in 0..n_chunks {
            acc += weight(ci);
            while next < workers && acc * workers >= total * next {
                bounds.push(ci + 1);
                next += 1;
            }
        }
        while bounds.len() < workers {
            bounds.push(n_chunks);
        }
        bounds.push(n_chunks);
        std::thread::scope(|scope| {
            let f = &f;
            let mut rest = out;
            let mut consumed = 0usize;
            for w in 0..workers {
                let end_el = (bounds[w + 1] * chunk_len).min(len);
                if end_el <= consumed {
                    continue; // empty block (heavily skewed weights)
                }
                let (mine, tail) = std::mem::take(&mut rest).split_at_mut(end_el - consumed);
                rest = tail;
                let base = consumed;
                consumed = end_el;
                scope.spawn(move || {
                    IN_POOL_WORKER.with(|c| c.set(true));
                    for (ci, chunk) in mine.chunks_mut(chunk_len).enumerate() {
                        f(base + ci * chunk_len, chunk);
                    }
                });
            }
        });
    }

    /// `[f(0), f(1), ..., f(n-1)]` computed concurrently, collected in
    /// index order. Used for coarse-grained tasks (one item = one model
    /// solve), so no work gate is applied.
    pub fn map<R, F>(&self, n: usize, f: F) -> Vec<R>
    where
        R: Send,
        F: Fn(usize) -> R + Sync,
    {
        let mut out: Vec<Option<R>> = Vec::with_capacity(n);
        out.resize_with(n, || None);
        self.run_chunks(&mut out, 1, |start, chunk| {
            for (off, slot) in chunk.iter_mut().enumerate() {
                *slot = Some(f(start + off));
            }
        });
        out.into_iter()
            .map(|r| r.expect("pool map: index not produced"))
            .collect()
    }
}

impl Default for Pool {
    fn default() -> Self {
        global()
    }
}

/// Rows per Gram panel: a pool chunk covers `GRAM_PANEL_ROWS` rows of
/// the output, evaluated as one [`Kernel::eval_block`] panel so every
/// `b`-row tile loaded by [`crate::linalg::dot_block`] is reused across
/// the whole panel (single-row panels would reload the entire matrix
/// per row and get none of the tile-blocking win).
const GRAM_PANEL_ROWS: usize = 8;

/// Row-major Gram matrix `K(data, data)` on the batched kernel-compute
/// layer ([`crate::linalg`]): squared row norms are cached once, then
/// the upper triangle is evaluated in parallel [`GRAM_PANEL_ROWS`]-row
/// trapezoid panels (rows `[i0, i1)` x columns `[i0, n)` as one
/// [`Kernel::eval_block`] rectangle), and the strict lower triangle is
/// mirrored with cheap copies. Every entry is a pure function of its
/// two rows — `eval_block` values do not depend on panel geometry, and
/// the block kernel is exactly symmetric — so the result is bitwise
/// identical at any thread count, and identical to the entries a
/// [`crate::svdd::smo::LazyKernel`] column produces for the same pair.
/// The scalar reference
/// ([`crate::svdd::smo::DenseKernel::from_data_serial`]) agrees to
/// ULP-level relative tolerance only (different summation order).
pub fn gram(data: &Matrix, kernel: Kernel, pool: Pool) -> Vec<f64> {
    let n = data.rows();
    let mut k = vec![0.0; n * n];
    if n == 0 {
        return k;
    }
    let norms = crate::linalg::NormCache::new(data);
    let norms_ref = &norms;
    // triangle halves the panel-dot count; a panel's cost is the sum of
    // its rows' (n - i) entries, so worker blocks are weighted to keep
    // the split balanced
    let work = n * n * data.cols().max(1) / 2;
    // span only above the parallel-work floor — tiny Grams (seed solves,
    // tests) stay clock-free
    let mut span = if work >= MIN_PAR_WORK {
        crate::obs::Span::enter("gram.compute")
    } else {
        crate::obs::Span::disabled()
    };
    if span.is_live() {
        span.u64("rows", n as u64);
        span.u64("entries", (n * n) as u64);
        span.str("isa", crate::linalg::isa::selected_name());
    }
    let weight = |ci: usize| {
        let r0 = ci * GRAM_PANEL_ROWS;
        let r1 = (r0 + GRAM_PANEL_ROWS).min(n);
        (r0..r1).map(|i| n - i).sum()
    };
    let chunk_len = GRAM_PANEL_ROWS * n;
    pool.for_work(work).run_chunks_weighted(&mut k, chunk_len, weight, |start, chunk| {
        let i0 = start / n;
        let rows = chunk.len() / n;
        let width = n - i0;
        // rectangle [i0, i1) x [i0, n): the few sub-diagonal entries
        // (j < i inside the panel) are recomputed rather than special-
        // cased — they carry the same bits as their upper-triangle
        // mirrors (exact symmetry) and the mirror pass overwrites them.
        let mut panel = vec![0.0; rows * width];
        kernel.eval_block(data, norms_ref, i0..i0 + rows, data, norms_ref, i0..n, &mut panel);
        for (r, prow) in panel.chunks(width).enumerate() {
            chunk[r * n + i0..(r + 1) * n].copy_from_slice(prow);
        }
    });
    for i in 1..n {
        for j in 0..i {
            k[i * n + j] = k[j * n + i];
        }
    }
    k
}

/// Native [`crate::sampling::GramBackend`] that computes sample/union
/// Gram matrices on the pool — the multi-core fallback when no XLA
/// artifact covers the shape.
#[derive(Clone, Copy, Debug, Default)]
pub struct PooledGram {
    pool: Option<Pool>,
}

impl PooledGram {
    /// Backend on the global pool.
    pub fn new() -> PooledGram {
        PooledGram { pool: None }
    }

    /// Backend pinned to an explicit pool (tests, benches).
    pub fn with_pool(pool: Pool) -> PooledGram {
        PooledGram { pool: Some(pool) }
    }

    fn pool(&self) -> Pool {
        self.pool.unwrap_or_else(global)
    }
}

impl crate::sampling::GramBackend for PooledGram {
    fn gram(&self, data: &Matrix, kernel: Kernel) -> Option<Vec<f64>> {
        Some(gram(data, kernel, self.pool()))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn thread_count_parses() {
        assert_eq!(ThreadCount::parse("auto").unwrap(), ThreadCount::Auto);
        assert_eq!(ThreadCount::parse("4").unwrap(), ThreadCount::Fixed(4));
        assert!(ThreadCount::parse("0").is_err());
        assert!(ThreadCount::parse("-1").is_err());
        assert!(ThreadCount::parse("many").is_err());
    }

    #[test]
    fn thread_count_resolves_positive() {
        assert!(ThreadCount::Auto.resolve() >= 1);
        assert_eq!(ThreadCount::Fixed(3).resolve(), 3);
        assert_eq!(ThreadCount::Fixed(0).resolve(), 1);
    }

    #[test]
    fn pool_clamps_to_one() {
        assert_eq!(Pool::new(0).threads(), 1);
        assert_eq!(Pool::serial().threads(), 1);
        assert!(Pool::auto().threads() >= 1);
    }

    #[test]
    fn for_work_gates_small_jobs() {
        let p = Pool::new(8);
        assert_eq!(p.for_work(10).threads(), 1);
        assert_eq!(p.for_work(MIN_PAR_WORK).threads(), 8);
    }

    #[test]
    fn run_chunks_fills_every_index() {
        for &threads in &[1usize, 2, 3, 8] {
            for &len in &[0usize, 1, 7, 64, 1000] {
                for &chunk in &[1usize, 7, 64, 4096] {
                    let mut out = vec![usize::MAX; len];
                    Pool::new(threads).run_chunks(&mut out, chunk, |start, c| {
                        for (off, slot) in c.iter_mut().enumerate() {
                            *slot = start + off;
                        }
                    });
                    for (i, &v) in out.iter().enumerate() {
                        assert_eq!(v, i, "threads={threads} len={len} chunk={chunk}");
                    }
                }
            }
        }
    }

    #[test]
    fn run_chunks_starts_are_chunk_aligned() {
        let starts = std::sync::Mutex::new(Vec::new());
        let mut out = vec![0u8; 103];
        Pool::new(4).run_chunks(&mut out, 10, |start, chunk| {
            assert_eq!(start % 10, 0);
            assert!(chunk.len() == 10 || start + chunk.len() == 103);
            starts.lock().unwrap().push(start);
        });
        let mut got = starts.into_inner().unwrap();
        got.sort_unstable();
        assert_eq!(got, (0..11).map(|c| c * 10).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_chunks_match_uniform_chunks() {
        let fill = |start: usize, c: &mut [usize]| {
            for (off, slot) in c.iter_mut().enumerate() {
                *slot = start + off;
            }
        };
        for &threads in &[1usize, 2, 3, 8] {
            for &len in &[1usize, 64, 1000] {
                let mut a = vec![usize::MAX; len];
                let mut b = vec![usize::MAX; len];
                Pool::new(threads).run_chunks(&mut a, 10, fill);
                Pool::new(threads).run_chunks_weighted(&mut b, 10, |ci| ci * ci + 1, fill);
                assert_eq!(a, b, "threads={threads} len={len}");
            }
        }
    }

    #[test]
    fn weighted_chunks_handle_skewed_and_zero_weights() {
        let mut out = vec![usize::MAX; 57];
        let huge_first = |ci: usize| if ci == 0 { 1000 } else { 0 };
        Pool::new(4).run_chunks_weighted(&mut out, 5, huge_first, |start, c| {
            for (off, slot) in c.iter_mut().enumerate() {
                *slot = start + off;
            }
        });
        for (i, &v) in out.iter().enumerate() {
            assert_eq!(v, i);
        }
    }

    #[test]
    fn map_preserves_index_order() {
        for &threads in &[1usize, 2, 8] {
            let got = Pool::new(threads).map(100, |i| i * i);
            let want: Vec<usize> = (0..100).map(|i| i * i).collect();
            assert_eq!(got, want);
        }
    }

    #[test]
    fn map_empty_is_empty() {
        let got: Vec<usize> = Pool::new(4).map(0, |i| i);
        assert!(got.is_empty());
    }

    #[test]
    fn gram_matches_block_reference_and_scalar_tolerance() {
        // 41-d rows mimic the Tennessee plant shape. The bitwise anchor
        // is the per-pair block evaluation (1x1 panels — eval_block
        // values are independent of panel geometry); the scalar
        // `Kernel::eval` triangle agrees to tolerance only.
        let mut rng = crate::util::rng::Xoshiro256::new(9);
        let rows: Vec<Vec<f64>> = (0..37)
            .map(|_| (0..41).map(|_| rng.normal()).collect())
            .collect();
        let data = Matrix::from_rows(&rows).unwrap();
        let kernel = Kernel::gaussian(1.7);
        let n = data.rows();
        let norms = crate::linalg::NormCache::new(&data);
        let mut want = vec![0.0; n * n];
        for i in 0..n {
            for j in 0..n {
                let mut one = [0.0];
                kernel.eval_block(&data, &norms, i..i + 1, &data, &norms, j..j + 1, &mut one);
                want[i * n + j] = one[0];
            }
        }
        for &threads in &[1usize, 2, 8] {
            let got = gram(&data, kernel, Pool::new(threads));
            assert_eq!(got, want, "threads={threads}");
        }
        for i in 0..n {
            for j in 0..n {
                let scalar = kernel.eval(data.row(i), data.row(j));
                assert!(
                    (want[i * n + j] - scalar).abs() <= 1e-12,
                    "({i},{j}): block {} vs scalar {scalar}",
                    want[i * n + j]
                );
            }
        }
    }

    #[test]
    fn global_install_roundtrip() {
        // default (nothing installed) resolves to >= 1 threads
        assert!(global().threads() >= 1);
        install(ParallelismConfig { threads: ThreadCount::Fixed(3) });
        assert_eq!(global().threads(), 3);
        install(ParallelismConfig { threads: ThreadCount::Auto });
        assert!(global().threads() >= 1);
    }

    #[test]
    fn global_pool_is_serial_inside_pool_workers() {
        // nested fan-outs must not multiply: a worker asking for the
        // global pool gets the serial one
        let inner = Pool::new(4).map(4, |_| global().threads());
        assert!(inner.iter().all(|&t| t == 1), "nested global pools: {inner:?}");
        // the calling thread is unaffected
        assert!(global().threads() >= 1);
    }

    #[test]
    fn worker_panic_propagates() {
        let caught = std::panic::catch_unwind(|| {
            let mut out = vec![0usize; 64];
            Pool::new(4).run_chunks(&mut out, 1, |start, _| {
                if start == 63 {
                    panic!("worker boom");
                }
            });
        });
        assert!(caught.is_err());
    }
}
