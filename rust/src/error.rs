//! Crate-wide error type.

/// All fallible public APIs in this crate return [`Result<T>`].
#[derive(Debug, thiserror::Error)]
pub enum Error {
    /// Invalid user input (bad config value, empty data set, ...).
    #[error("invalid input: {0}")]
    InvalidInput(String),

    /// The QP solver failed to make progress / converge.
    #[error("solver failure: {0}")]
    Solver(String),

    /// AOT artifact registry / PJRT runtime problems.
    #[error("runtime: {0}")]
    Runtime(String),

    /// Distributed protocol errors (framing, version, channel death).
    #[error("distributed: {0}")]
    Distributed(String),

    /// Configuration file / CLI parsing problems.
    #[error("config: {0}")]
    Config(String),

    /// JSON parse errors from the mini parser.
    #[error("json: {0}")]
    Json(String),

    #[error(transparent)]
    Io(#[from] std::io::Error),

    /// Errors bubbled out of the `xla` crate (PJRT).
    #[error("xla: {0}")]
    Xla(String),
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Shorthand used all over the crate.
    pub fn invalid(msg: impl Into<String>) -> Self {
        Error::InvalidInput(msg.into())
    }
}
