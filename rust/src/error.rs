//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls (the offline build vendors no
//! proc-macro crates, so no `thiserror`).

use std::fmt;

/// All fallible public APIs in this crate return [`Result<T>`].
#[derive(Debug)]
pub enum Error {
    /// Invalid user input (bad config value, empty data set, ...).
    InvalidInput(String),

    /// The QP solver failed to make progress / converge.
    Solver(String),

    /// AOT artifact registry / PJRT runtime problems.
    Runtime(String),

    /// Distributed protocol errors (framing, version, channel death).
    Distributed(String),

    /// Configuration file / CLI parsing problems.
    Config(String),

    /// The serving path shed this request under load (bounded queue /
    /// in-flight cap). Retryable: the caller should back off and retry
    /// rather than treat the request as invalid.
    Overloaded(String),

    /// JSON parse errors from the mini parser.
    Json(String),

    /// Model registry problems (missing version, corrupt manifest, ...).
    Registry(String),

    Io(std::io::Error),

    /// Errors bubbled out of the `xla` crate (PJRT).
    Xla(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::InvalidInput(m) => write!(f, "invalid input: {m}"),
            Error::Solver(m) => write!(f, "solver failure: {m}"),
            Error::Runtime(m) => write!(f, "runtime: {m}"),
            Error::Distributed(m) => write!(f, "distributed: {m}"),
            Error::Config(m) => write!(f, "config: {m}"),
            Error::Overloaded(m) => write!(f, "overloaded: {m}"),
            Error::Json(m) => write!(f, "json: {m}"),
            Error::Registry(m) => write!(f, "registry: {m}"),
            Error::Io(e) => write!(f, "{e}"),
            Error::Xla(m) => write!(f, "xla: {m}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e.to_string())
    }
}

pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    /// Shorthand used all over the crate.
    pub fn invalid(msg: impl Into<String>) -> Self {
        Error::InvalidInput(msg.into())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn display_prefixes_match_variants() {
        assert_eq!(Error::invalid("x").to_string(), "invalid input: x");
        assert_eq!(Error::Registry("gone".into()).to_string(), "registry: gone");
        assert_eq!(Error::Json("bad".into()).to_string(), "json: bad");
        assert_eq!(
            Error::Overloaded("queue full".into()).to_string(),
            "overloaded: queue full"
        );
    }

    #[test]
    fn io_errors_are_transparent() {
        let io = std::io::Error::new(std::io::ErrorKind::NotFound, "missing");
        let e: Error = io.into();
        assert_eq!(e.to_string(), "missing");
        assert!(std::error::Error::source(&e).is_some());
    }
}
