//! Timing helpers shared by the bench harness and the trainers.

use std::time::{Duration, Instant};

/// A simple stopwatch.
#[derive(Clone, Debug)]
pub struct Stopwatch {
    start: Instant,
}

impl Stopwatch {
    pub fn start() -> Self {
        Stopwatch { start: Instant::now() }
    }

    pub fn elapsed(&self) -> Duration {
        self.start.elapsed()
    }

    pub fn elapsed_secs(&self) -> f64 {
        self.elapsed().as_secs_f64()
    }

    pub fn restart(&mut self) -> Duration {
        let e = self.start.elapsed();
        self.start = Instant::now();
        e
    }
}

/// Human-friendly duration rendering for the bench tables
/// ("1.98 sec", "32.0 min", "412 us" ...).
pub fn fmt_duration(secs: f64) -> String {
    if secs < 0.0 {
        return format!("-{}", fmt_duration(-secs));
    }
    if secs < 1e-3 {
        format!("{:.0} us", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.1} ms", secs * 1e3)
    } else if secs < 120.0 {
        format!("{secs:.2} sec")
    } else if secs < 7200.0 {
        format!("{:.1} min", secs / 60.0)
    } else {
        format!("{:.2} hr", secs / 3600.0)
    }
}

/// Time a closure, returning (result, seconds).
pub fn timed<T>(f: impl FnOnce() -> T) -> (T, f64) {
    let sw = Stopwatch::start();
    let out = f();
    (out, sw.elapsed_secs())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn stopwatch_monotone() {
        let sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(5));
        assert!(sw.elapsed_secs() >= 0.005);
    }

    #[test]
    fn restart_resets() {
        let mut sw = Stopwatch::start();
        std::thread::sleep(Duration::from_millis(3));
        let first = sw.restart();
        assert!(first.as_secs_f64() >= 0.003);
        assert!(sw.elapsed_secs() < first.as_secs_f64());
    }

    #[test]
    fn duration_formatting() {
        assert_eq!(fmt_duration(0.0000005), "0 us");
        assert_eq!(fmt_duration(0.0123), "12.3 ms");
        assert_eq!(fmt_duration(1.98), "1.98 sec");
        assert_eq!(fmt_duration(1920.0), "32.0 min");
        assert_eq!(fmt_duration(8000.0), "2.22 hr");
    }

    #[test]
    fn timed_returns_value() {
        let (v, secs) = timed(|| 21 * 2);
        assert_eq!(v, 42);
        assert!(secs >= 0.0);
    }
}
