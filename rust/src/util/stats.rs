//! Descriptive statistics used by the bench harnesses and the paper's
//! box-whisker figures (Figs 14–16), plus the least-squares fit used to
//! extrapolate the full-SVDD cost curve (Fig 1).

/// Arithmetic mean; 0 for empty input.
pub fn mean(xs: &[f64]) -> f64 {
    if xs.is_empty() {
        return 0.0;
    }
    xs.iter().sum::<f64>() / xs.len() as f64
}

/// Population standard deviation.
pub fn std_dev(xs: &[f64]) -> f64 {
    if xs.len() < 2 {
        return 0.0;
    }
    let m = mean(xs);
    (xs.iter().map(|x| (x - m) * (x - m)).sum::<f64>() / xs.len() as f64).sqrt()
}

/// Linear-interpolated quantile (type-7, the R/numpy default).
/// `q` in [0, 1]. Input need not be sorted.
pub fn quantile(xs: &[f64], q: f64) -> f64 {
    assert!(!xs.is_empty(), "quantile of empty slice");
    assert!((0.0..=1.0).contains(&q), "q={q} out of [0,1]");
    let mut v: Vec<f64> = xs.to_vec();
    v.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let pos = q * (v.len() - 1) as f64;
    let lo = pos.floor() as usize;
    let hi = pos.ceil() as usize;
    if lo == hi {
        v[lo]
    } else {
        v[lo] + (pos - lo as f64) * (v[hi] - v[lo])
    }
}

/// Five-number summary + mean — exactly the glyphs of the paper's
/// box-whisker plots (whiskers at min/max, box at Q1/Q3, line at the
/// median, diamond at the mean).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct BoxStats {
    pub min: f64,
    pub q1: f64,
    pub median: f64,
    pub q3: f64,
    pub max: f64,
    pub mean: f64,
    pub n: usize,
}

impl BoxStats {
    pub fn from(xs: &[f64]) -> BoxStats {
        assert!(!xs.is_empty(), "BoxStats of empty slice");
        BoxStats {
            min: quantile(xs, 0.0),
            q1: quantile(xs, 0.25),
            median: quantile(xs, 0.5),
            q3: quantile(xs, 0.75),
            max: quantile(xs, 1.0),
            mean: mean(xs),
            n: xs.len(),
        }
    }
}

impl std::fmt::Display for BoxStats {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(
            f,
            "min={:.4} q1={:.4} med={:.4} q3={:.4} max={:.4} mean={:.4} (n={})",
            self.min, self.q1, self.median, self.q3, self.max, self.mean, self.n
        )
    }
}

/// Least-squares fit of `y = a + b x`. Returns `(a, b)`.
pub fn linear_fit(x: &[f64], y: &[f64]) -> (f64, f64) {
    assert_eq!(x.len(), y.len());
    assert!(x.len() >= 2, "linear_fit needs >= 2 points");
    let mx = mean(x);
    let my = mean(y);
    let sxy: f64 = x.iter().zip(y).map(|(a, b)| (a - mx) * (b - my)).sum();
    let sxx: f64 = x.iter().map(|a| (a - mx) * (a - mx)).sum();
    if sxx == 0.0 {
        return (my, 0.0);
    }
    let b = sxy / sxx;
    (my - b * mx, b)
}

/// Power-law fit `y = c * x^p` via log-log least squares; returns `(c, p)`.
/// Used to extrapolate full-SVDD training time to the paper's 1.33 M rows.
pub fn power_fit(x: &[f64], y: &[f64]) -> (f64, f64) {
    let lx: Vec<f64> = x.iter().map(|v| v.ln()).collect();
    let ly: Vec<f64> = y.iter().map(|v| v.max(1e-12).ln()).collect();
    let (a, b) = linear_fit(&lx, &ly);
    (a.exp(), b)
}

/// Pearson correlation, for sanity checks in tests.
pub fn pearson(x: &[f64], y: &[f64]) -> f64 {
    let mx = mean(x);
    let my = mean(y);
    let num: f64 = x.iter().zip(y).map(|(a, b)| (a - mx) * (b - my)).sum();
    let dx: f64 = x.iter().map(|a| (a - mx) * (a - mx)).sum::<f64>().sqrt();
    let dy: f64 = y.iter().map(|b| (b - my) * (b - my)).sum::<f64>().sqrt();
    if dx == 0.0 || dy == 0.0 {
        0.0
    } else {
        num / (dx * dy)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mean_and_std() {
        assert_eq!(mean(&[1.0, 2.0, 3.0]), 2.0);
        assert!((std_dev(&[2.0, 4.0, 4.0, 4.0, 5.0, 5.0, 7.0, 9.0]) - 2.0).abs() < 1e-12);
        assert_eq!(mean(&[]), 0.0);
    }

    #[test]
    fn quantile_interpolates() {
        let xs = [1.0, 2.0, 3.0, 4.0];
        assert_eq!(quantile(&xs, 0.0), 1.0);
        assert_eq!(quantile(&xs, 1.0), 4.0);
        assert_eq!(quantile(&xs, 0.5), 2.5);
        assert!((quantile(&xs, 0.25) - 1.75).abs() < 1e-12);
    }

    #[test]
    #[should_panic]
    fn quantile_empty_panics() {
        quantile(&[], 0.5);
    }

    #[test]
    fn box_stats_summary() {
        let xs: Vec<f64> = (1..=9).map(|i| i as f64).collect();
        let b = BoxStats::from(&xs);
        assert_eq!(b.min, 1.0);
        assert_eq!(b.median, 5.0);
        assert_eq!(b.max, 9.0);
        assert_eq!(b.mean, 5.0);
        assert_eq!(b.n, 9);
    }

    #[test]
    fn linear_fit_exact_line() {
        let x = [1.0, 2.0, 3.0, 4.0];
        let y = [3.0, 5.0, 7.0, 9.0]; // y = 1 + 2x
        let (a, b) = linear_fit(&x, &y);
        assert!((a - 1.0).abs() < 1e-10);
        assert!((b - 2.0).abs() < 1e-10);
    }

    #[test]
    fn power_fit_recovers_exponent() {
        let x = [100.0, 1000.0, 10_000.0, 100_000.0];
        let y: Vec<f64> = x.iter().map(|v: &f64| 3e-7 * v.powf(1.8)).collect();
        let (c, p) = power_fit(&x, &y);
        assert!((p - 1.8).abs() < 1e-6, "p={p}");
        assert!((c - 3e-7).abs() / 3e-7 < 1e-6, "c={c}");
    }

    #[test]
    fn pearson_perfect_correlation() {
        let x = [1.0, 2.0, 3.0];
        let y = [2.0, 4.0, 6.0];
        assert!((pearson(&x, &y) - 1.0).abs() < 1e-12);
        let z = [6.0, 4.0, 2.0];
        assert!((pearson(&x, &z) + 1.0).abs() < 1e-12);
    }
}
