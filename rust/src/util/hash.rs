//! FNV-1a 64-bit hashing for content addressing.
//!
//! The model registry derives version ids from model contents and
//! records a fingerprint of the training data alongside each version.
//! FNV-1a is not cryptographic — it is a fast, dependency-free, stable
//! hash whose collisions are irrelevant at registry scale (dozens of
//! versions), and whose output is identical across platforms because
//! every input is serialized to little-endian bytes first.

use crate::util::matrix::Matrix;

const FNV_OFFSET: u64 = 0xcbf2_9ce4_8422_2325;
const FNV_PRIME: u64 = 0x0000_0100_0000_01b3;

/// Incremental FNV-1a 64 hasher.
#[derive(Clone, Debug)]
pub struct Fnv1a(u64);

impl Default for Fnv1a {
    fn default() -> Self {
        Self::new()
    }
}

impl Fnv1a {
    pub fn new() -> Fnv1a {
        Fnv1a(FNV_OFFSET)
    }

    pub fn write(&mut self, bytes: &[u8]) {
        for &b in bytes {
            self.0 ^= b as u64;
            self.0 = self.0.wrapping_mul(FNV_PRIME);
        }
    }

    pub fn write_u8(&mut self, v: u8) {
        self.write(&[v]);
    }

    pub fn write_u64(&mut self, v: u64) {
        self.write(&v.to_le_bytes());
    }

    /// Hash the IEEE-754 bit pattern (distinguishes -0.0 / 0.0 and all
    /// NaN payloads, matching the bitwise row model of
    /// [`Matrix::dedup_rows`]).
    pub fn write_f64(&mut self, v: f64) {
        self.write(&v.to_bits().to_le_bytes());
    }

    pub fn finish(&self) -> u64 {
        self.0
    }
}

/// One-shot hash of a byte slice.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h = Fnv1a::new();
    h.write(bytes);
    h.finish()
}

/// Stable fingerprint of a data matrix (shape + element bits). The
/// registry stores this next to each trained version so "was this
/// champion trained on the same window?" is answerable after the fact.
pub fn fingerprint_matrix(m: &Matrix) -> u64 {
    let mut h = Fnv1a::new();
    h.write_u64(m.rows() as u64);
    h.write_u64(m.cols() as u64);
    for &v in m.as_slice() {
        h.write_f64(v);
    }
    h.finish()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn known_vectors() {
        // Standard FNV-1a 64 test vectors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
    }

    #[test]
    fn incremental_matches_oneshot() {
        let mut h = Fnv1a::new();
        h.write(b"foo");
        h.write(b"bar");
        assert_eq!(h.finish(), fnv1a64(b"foobar"));
    }

    #[test]
    fn matrix_fingerprint_sensitive_to_shape_and_values() {
        let a = Matrix::from_vec(vec![1.0, 2.0, 3.0, 4.0], 2, 2).unwrap();
        let b = Matrix::from_vec(vec![1.0, 2.0, 3.0, 4.0], 4, 1).unwrap();
        let c = Matrix::from_vec(vec![1.0, 2.0, 3.0, 5.0], 2, 2).unwrap();
        assert_ne!(fingerprint_matrix(&a), fingerprint_matrix(&b));
        assert_ne!(fingerprint_matrix(&a), fingerprint_matrix(&c));
        assert_eq!(fingerprint_matrix(&a), fingerprint_matrix(&a.clone()));
    }

    #[test]
    fn float_bits_distinguish_signed_zero() {
        let mut h1 = Fnv1a::new();
        h1.write_f64(0.0);
        let mut h2 = Fnv1a::new();
        h2.write_f64(-0.0);
        assert_ne!(h1.finish(), h2.finish());
    }
}
