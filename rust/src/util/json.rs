//! Minimal JSON: a value model, a recursive-descent parser and a writer.
//!
//! The vendored crate set has `serde_derive` but not the `serde` facade,
//! so this module provides the small amount of JSON the system needs:
//! reading `artifacts/manifest.json`, reading registry manifests and
//! run configs, and writing bench results. It is a complete JSON parser
//! (objects, arrays, strings with escapes — including `\u` surrogate
//! pairs for non-BMP scalars — numbers, booleans, null); unpaired
//! surrogates are rejected rather than silently replaced.

use std::collections::BTreeMap;
use std::fmt;
use std::fmt::Write as _;

use crate::error::{Error, Result};

/// A JSON value. Objects use a BTreeMap so output is deterministic.
#[derive(Clone, Debug, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn parse(text: &str) -> Result<Json> {
        let mut p = Parser { bytes: text.as_bytes(), pos: 0 };
        p.skip_ws();
        let v = p.value()?;
        p.skip_ws();
        if p.pos != p.bytes.len() {
            return Err(Error::Json(format!("trailing data at byte {}", p.pos)));
        }
        Ok(v)
    }

    // ------------------------------------------------------- accessors

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(x) => Some(*x),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().map(|x| x as usize)
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    /// `obj.get(key)` that errors with context instead of None.
    pub fn req(&self, key: &str) -> Result<&Json> {
        self.get(key)
            .ok_or_else(|| Error::Json(format!("missing key '{key}'")))
    }

    // --------------------------------------------------------- writing
    // (compact writing is `Display`, so `json.to_string()` comes from
    // the blanket `ToString` impl)

    pub fn to_string_pretty(&self) -> String {
        let mut s = String::new();
        self.write(&mut s, Some(2), 0);
        s
    }

    fn write(&self, out: &mut String, indent: Option<usize>, depth: usize) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 1e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(out, s),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    item.write(out, indent, depth + 1);
                }
                if !v.is_empty() {
                    newline(out, indent, depth);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, val)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    newline(out, indent, depth + 1);
                    write_escaped(out, k);
                    out.push(':');
                    if indent.is_some() {
                        out.push(' ');
                    }
                    val.write(out, indent, depth + 1);
                }
                if !m.is_empty() {
                    newline(out, indent, depth);
                }
                out.push('}');
            }
        }
    }
}

impl fmt::Display for Json {
    /// Compact (single-line) JSON serialization.
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        let mut s = String::new();
        self.write(&mut s, None, 0);
        f.write_str(&s)
    }
}

fn newline(out: &mut String, indent: Option<usize>, depth: usize) {
    if let Some(w) = indent {
        out.push('\n');
        for _ in 0..w * depth {
            out.push(' ');
        }
    }
}

fn write_escaped(out: &mut String, s: &str) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

/// Convenience builders used by the result sinks.
pub fn obj(pairs: Vec<(&str, Json)>) -> Json {
    Json::Obj(pairs.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

pub fn num(x: f64) -> Json {
    Json::Num(x)
}

pub fn s(x: impl Into<String>) -> Json {
    Json::Str(x.into())
}

pub fn arr(items: Vec<Json>) -> Json {
    Json::Arr(items)
}

struct Parser<'a> {
    bytes: &'a [u8],
    pos: usize,
}

impl<'a> Parser<'a> {
    fn skip_ws(&mut self) {
        while self.pos < self.bytes.len()
            && matches!(self.bytes[self.pos], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.pos += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.bytes.get(self.pos).copied()
    }

    fn eat(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.pos += 1;
            Ok(())
        } else {
            Err(Error::Json(format!(
                "expected '{}' at byte {}, found {:?}",
                c as char,
                self.pos,
                self.peek().map(|b| b as char)
            )))
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.skip_ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => Err(Error::Json(format!(
                "unexpected {:?} at byte {}",
                other.map(|b| b as char),
                self.pos
            ))),
        }
    }

    fn lit(&mut self, word: &str, v: Json) -> Result<Json> {
        if self.bytes[self.pos..].starts_with(word.as_bytes()) {
            self.pos += word.len();
            Ok(v)
        } else {
            Err(Error::Json(format!("bad literal at byte {}", self.pos)))
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.eat(b'{')?;
        let mut m = BTreeMap::new();
        self.skip_ws();
        if self.peek() == Some(b'}') {
            self.pos += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.skip_ws();
            let key = self.string()?;
            self.skip_ws();
            self.eat(b':')?;
            let val = self.value()?;
            m.insert(key, val);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b'}') => {
                    self.pos += 1;
                    return Ok(Json::Obj(m));
                }
                _ => return Err(Error::Json(format!("bad object at byte {}", self.pos))),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.eat(b'[')?;
        let mut v = Vec::new();
        self.skip_ws();
        if self.peek() == Some(b']') {
            self.pos += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.skip_ws();
            match self.peek() {
                Some(b',') => self.pos += 1,
                Some(b']') => {
                    self.pos += 1;
                    return Ok(Json::Arr(v));
                }
                _ => return Err(Error::Json(format!("bad array at byte {}", self.pos))),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(Error::Json("unterminated string".into())),
                Some(b'"') => {
                    self.pos += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.pos += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'b') => s.push('\u{8}'),
                        Some(b'f') => s.push('\u{c}'),
                        Some(b'u') => {
                            self.pos += 1; // past 'u'
                            let hi = self.hex4()?;
                            let ch = if (0xD800..0xDC00).contains(&hi) {
                                // High surrogate: a low surrogate escape
                                // must follow; together they encode one
                                // scalar beyond the BMP (RFC 8259 §7).
                                if self.peek() == Some(b'\\')
                                    && self.bytes.get(self.pos + 1) == Some(&b'u')
                                {
                                    self.pos += 2;
                                    let lo = self.hex4()?;
                                    if !(0xDC00..0xE000).contains(&lo) {
                                        return Err(Error::Json(format!(
                                            "invalid low surrogate \\u{lo:04x} after \\u{hi:04x}"
                                        )));
                                    }
                                    let code = 0x1_0000 + ((hi - 0xD800) << 10) + (lo - 0xDC00);
                                    char::from_u32(code)
                                        .expect("surrogate pair decodes to a valid scalar")
                                } else {
                                    return Err(Error::Json(format!(
                                        "unpaired high surrogate \\u{hi:04x}"
                                    )));
                                }
                            } else if (0xDC00..0xE000).contains(&hi) {
                                return Err(Error::Json(format!(
                                    "unpaired low surrogate \\u{hi:04x}"
                                )));
                            } else {
                                char::from_u32(hi).expect("non-surrogate BMP scalar")
                            };
                            s.push(ch);
                            continue;
                        }
                        other => {
                            return Err(Error::Json(format!("bad escape {other:?}")));
                        }
                    }
                    self.pos += 1;
                }
                Some(_) => {
                    // consume one UTF-8 scalar
                    let start = self.pos;
                    let rest = &self.bytes[start..];
                    let txt = std::str::from_utf8(rest)
                        .map_err(|_| Error::Json("invalid utf-8".into()))?;
                    let c = txt.chars().next().unwrap();
                    s.push(c);
                    self.pos += c.len_utf8();
                }
            }
        }
    }

    /// Four hex digits at the cursor (the payload of a `\u` escape).
    fn hex4(&mut self) -> Result<u32> {
        let hex = self
            .bytes
            .get(self.pos..self.pos + 4)
            .ok_or_else(|| Error::Json("truncated \\u escape".into()))?;
        if !hex.iter().all(|b| b.is_ascii_hexdigit()) {
            return Err(Error::Json(format!(
                "bad \\u escape at byte {}",
                self.pos
            )));
        }
        let code = u32::from_str_radix(std::str::from_utf8(hex).unwrap(), 16)
            .expect("validated hex digits");
        self.pos += 4;
        Ok(code)
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.pos;
        if self.peek() == Some(b'-') {
            self.pos += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.pos += 1;
        }
        let text = std::str::from_utf8(&self.bytes[start..self.pos]).unwrap();
        text.parse::<f64>()
            .map(Json::Num)
            .map_err(|_| Error::Json(format!("bad number '{text}'")))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse(r#""hi""#).unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parse_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": "x"}], "c": null}"#).unwrap();
        assert_eq!(v.get("a").unwrap().as_arr().unwrap().len(), 3);
        assert_eq!(
            v.get("a").unwrap().as_arr().unwrap()[2]
                .get("b")
                .unwrap()
                .as_str(),
            Some("x")
        );
        assert_eq!(v.get("c"), Some(&Json::Null));
    }

    #[test]
    fn parse_escapes() {
        let v = Json::parse(r#""a\n\t\"\\ b A""#).unwrap();
        assert_eq!(v.as_str(), Some("a\n\t\"\\ b A"));
    }

    #[test]
    fn roundtrip() {
        let src = obj(vec![
            ("name", s("score_m2")),
            ("b", num(4096.0)),
            ("ok", Json::Bool(true)),
            ("xs", arr(vec![num(1.0), num(2.5)])),
        ]);
        let text = src.to_string_pretty();
        assert_eq!(Json::parse(&text).unwrap(), src);
        let text2 = src.to_string();
        assert_eq!(Json::parse(&text2).unwrap(), src);
    }

    #[test]
    fn parse_bmp_unicode_escapes() {
        let v = Json::parse(r#""Aé中""#).unwrap();
        assert_eq!(v.as_str(), Some("Aé中"));
    }

    #[test]
    fn parse_surrogate_pairs_beyond_bmp() {
        // U+1F600 GRINNING FACE and U+10348 GOTHIC LETTER HWAIR
        let v = Json::parse(r#""😀 𐍈""#).unwrap();
        assert_eq!(v.as_str(), Some("\u{1F600} \u{10348}"));
    }

    #[test]
    fn astral_roundtrip_raw_and_escaped() {
        let src = Json::Str("mixed \u{1F680} text \u{10348}…".into());
        // writer emits raw UTF-8; the parser must read it back exactly
        let back = Json::parse(&src.to_string()).unwrap();
        assert_eq!(back, src);
        // and the surrogate-pair spelling of the same string parses equal
        let escaped = "\"mixed \\ud83d\\ude80 text \\ud800\\udf48…\"";
        assert_eq!(Json::parse(escaped).unwrap(), src);
    }

    #[test]
    fn unpaired_surrogates_rejected() {
        assert!(Json::parse(r#""\ud83d""#).is_err()); // lone high
        assert!(Json::parse(r#""\ud83d!""#).is_err()); // high + raw char
        assert!(Json::parse(r#""\ud83dA""#).is_err()); // high + BMP
        assert!(Json::parse(r#""\ude00""#).is_err()); // lone low
    }

    #[test]
    fn malformed_unicode_escape_rejected() {
        assert!(Json::parse(r#""\u12""#).is_err());
        assert!(Json::parse(r#""\uzzzz""#).is_err());
        assert!(Json::parse(r#""\u+123""#).is_err());
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse(r#"{"a" 1}"#).is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("tru").is_err());
    }

    #[test]
    fn req_reports_missing_key() {
        let v = Json::parse("{}").unwrap();
        assert!(v.req("nope").is_err());
    }

    #[test]
    fn integers_render_without_fraction() {
        assert_eq!(num(4096.0).to_string(), "4096");
        assert_eq!(num(0.5).to_string(), "0.5");
    }
}
