//! Flat row-major matrix of `f64` observations.
//!
//! The whole library moves data around as [`Matrix`] — a contiguous
//! row-major buffer with `rows x cols` shape. Rows are observations,
//! columns are features. f64 is the solver precision (LIBSVM uses
//! doubles too); conversion to the f32 XLA boundary happens in
//! [`crate::runtime`].

use crate::error::{Error, Result};

/// Dense row-major `rows x cols` matrix of `f64`.
#[derive(Clone, Debug, PartialEq)]
pub struct Matrix {
    data: Vec<f64>,
    rows: usize,
    cols: usize,
}

impl Matrix {
    /// All-zero matrix.
    pub fn zeros(rows: usize, cols: usize) -> Self {
        Matrix { data: vec![0.0; rows * cols], rows, cols }
    }

    /// Take ownership of a flat buffer.
    pub fn from_vec(data: Vec<f64>, rows: usize, cols: usize) -> Result<Self> {
        if data.len() != rows * cols {
            return Err(Error::invalid(format!(
                "matrix buffer has {} elements, expected {rows}x{cols}",
                data.len()
            )));
        }
        Ok(Matrix { data, rows, cols })
    }

    /// Build from row slices (all must share a length).
    pub fn from_rows(rows: &[Vec<f64>]) -> Result<Self> {
        if rows.is_empty() {
            return Err(Error::invalid("from_rows: no rows"));
        }
        let cols = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * cols);
        for (i, r) in rows.iter().enumerate() {
            if r.len() != cols {
                return Err(Error::invalid(format!(
                    "row {i} has {} cols, expected {cols}",
                    r.len()
                )));
            }
            data.extend_from_slice(r);
        }
        Ok(Matrix { data, rows: rows.len(), cols })
    }

    #[inline]
    pub fn rows(&self) -> usize {
        self.rows
    }

    #[inline]
    pub fn cols(&self) -> usize {
        self.cols
    }

    #[inline]
    pub fn is_empty(&self) -> bool {
        self.rows == 0
    }

    /// Row `i` as a slice.
    #[inline]
    pub fn row(&self, i: usize) -> &[f64] {
        debug_assert!(i < self.rows);
        &self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn row_mut(&mut self, i: usize) -> &mut [f64] {
        debug_assert!(i < self.rows);
        &mut self.data[i * self.cols..(i + 1) * self.cols]
    }

    #[inline]
    pub fn get(&self, i: usize, j: usize) -> f64 {
        self.data[i * self.cols + j]
    }

    #[inline]
    pub fn set(&mut self, i: usize, j: usize, v: f64) {
        self.data[i * self.cols + j] = v;
    }

    /// Raw flat buffer (row-major).
    #[inline]
    pub fn as_slice(&self) -> &[f64] {
        &self.data
    }

    /// Gather a sub-matrix of the given row indices (duplicates allowed —
    /// the sampling trainer draws with replacement).
    pub fn gather(&self, idx: &[usize]) -> Matrix {
        let mut data = Vec::with_capacity(idx.len() * self.cols);
        for &i in idx {
            data.extend_from_slice(self.row(i));
        }
        Matrix { data, rows: idx.len(), cols: self.cols }
    }

    /// Append all rows of `other` (must have matching `cols`).
    pub fn vstack(&self, other: &Matrix) -> Result<Matrix> {
        if self.cols != other.cols && !self.is_empty() && !other.is_empty() {
            return Err(Error::invalid(format!(
                "vstack: {} vs {} cols",
                self.cols, other.cols
            )));
        }
        let mut data = self.data.clone();
        data.extend_from_slice(&other.data);
        Ok(Matrix {
            data,
            rows: self.rows + other.rows,
            cols: if self.is_empty() { other.cols } else { self.cols },
        })
    }

    /// Squared euclidean distance between two rows of (possibly
    /// different) matrices.
    #[inline]
    pub fn sqdist(a: &[f64], b: &[f64]) -> f64 {
        debug_assert_eq!(a.len(), b.len());
        let mut s = 0.0;
        for (x, y) in a.iter().zip(b) {
            let d = x - y;
            s += d * d;
        }
        s
    }

    /// Deduplicate rows exactly (bitwise). Order-preserving, first
    /// occurrence wins. Used by the union step of Algorithm 1 so the
    /// master set never accumulates duplicate support vectors.
    pub fn dedup_rows(&self) -> Matrix {
        let mut seen: std::collections::HashSet<Vec<u64>> = Default::default();
        let mut keep: Vec<usize> = Vec::new();
        for i in 0..self.rows {
            let key: Vec<u64> = self.row(i).iter().map(|x| x.to_bits()).collect();
            if seen.insert(key) {
                keep.push(i);
            }
        }
        self.gather(&keep)
    }

    /// Per-column mean.
    pub fn col_means(&self) -> Vec<f64> {
        let mut m = vec![0.0; self.cols];
        for i in 0..self.rows {
            for (j, v) in self.row(i).iter().enumerate() {
                m[j] += v;
            }
        }
        for v in &mut m {
            *v /= self.rows.max(1) as f64;
        }
        m
    }

    /// Flat f32 copy (XLA boundary).
    pub fn to_f32(&self) -> Vec<f32> {
        self.data.iter().map(|&x| x as f32).collect()
    }
}

impl std::fmt::Display for Matrix {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        writeln!(f, "Matrix {}x{}", self.rows, self.cols)?;
        for i in 0..self.rows.min(6) {
            writeln!(f, "  {:?}", self.row(i))?;
        }
        if self.rows > 6 {
            writeln!(f, "  ... ({} more rows)", self.rows - 6)?;
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn construction_and_access() {
        let m = Matrix::from_vec(vec![1.0, 2.0, 3.0, 4.0, 5.0, 6.0], 2, 3).unwrap();
        assert_eq!(m.rows(), 2);
        assert_eq!(m.cols(), 3);
        assert_eq!(m.row(1), &[4.0, 5.0, 6.0]);
        assert_eq!(m.get(0, 2), 3.0);
    }

    #[test]
    fn bad_shape_rejected() {
        assert!(Matrix::from_vec(vec![1.0; 5], 2, 3).is_err());
    }

    #[test]
    fn from_rows_ragged_rejected() {
        assert!(Matrix::from_rows(&[vec![1.0], vec![1.0, 2.0]]).is_err());
    }

    #[test]
    fn gather_with_duplicates() {
        let m = Matrix::from_vec(vec![0.0, 1.0, 2.0, 3.0], 4, 1).unwrap();
        let g = m.gather(&[3, 0, 3]);
        assert_eq!(g.as_slice(), &[3.0, 0.0, 3.0]);
    }

    #[test]
    fn vstack_works() {
        let a = Matrix::from_vec(vec![1.0, 2.0], 1, 2).unwrap();
        let b = Matrix::from_vec(vec![3.0, 4.0, 5.0, 6.0], 2, 2).unwrap();
        let c = a.vstack(&b).unwrap();
        assert_eq!(c.rows(), 3);
        assert_eq!(c.row(2), &[5.0, 6.0]);
    }

    #[test]
    fn vstack_mismatched_rejected() {
        let a = Matrix::from_vec(vec![1.0, 2.0], 1, 2).unwrap();
        let b = Matrix::from_vec(vec![3.0], 1, 1).unwrap();
        assert!(a.vstack(&b).is_err());
    }

    #[test]
    fn sqdist_basic() {
        assert_eq!(Matrix::sqdist(&[0.0, 0.0], &[3.0, 4.0]), 25.0);
    }

    #[test]
    fn dedup_rows_keeps_first() {
        let m = Matrix::from_rows(&[
            vec![1.0, 2.0],
            vec![3.0, 4.0],
            vec![1.0, 2.0],
            vec![5.0, 6.0],
        ])
        .unwrap();
        let d = m.dedup_rows();
        assert_eq!(d.rows(), 3);
        assert_eq!(d.row(0), &[1.0, 2.0]);
        assert_eq!(d.row(2), &[5.0, 6.0]);
    }

    #[test]
    fn col_means() {
        let m = Matrix::from_rows(&[vec![1.0, 10.0], vec![3.0, 30.0]]).unwrap();
        assert_eq!(m.col_means(), vec![2.0, 20.0]);
    }

    #[test]
    fn to_f32_roundtrip() {
        let m = Matrix::from_vec(vec![1.5, -2.25], 1, 2).unwrap();
        assert_eq!(m.to_f32(), vec![1.5f32, -2.25f32]);
    }
}
