//! Deterministic pseudo-random generation.
//!
//! The vendored crate set has `rand_core` (traits) but not `rand`
//! (algorithms), so this module implements the generators the library
//! needs: SplitMix64 for seeding and **Xoshiro256++** as the workhorse
//! (Blackman & Vigna 2019 — the same generator the `rand_xoshiro` crate
//! ships). On top of the raw stream we provide the distributions used by
//! the data generators and the sampling trainer: uniform ranges,
//! Box–Muller normals, shuffling and with/without-replacement sampling.
//!
//! Every experiment in the repo takes an explicit `u64` seed so all
//! tables/figures regenerate bit-identically.

use rand_core::{impls, RngCore, SeedableRng};

/// SplitMix64 — used to expand a single `u64` seed into Xoshiro state.
#[derive(Clone, Debug)]
pub struct SplitMix64 {
    state: u64,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed }
    }

    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E37_79B9_7F4A_7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58_476D_1CE4_E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D0_49BB_1331_11EB);
        z ^ (z >> 31)
    }
}

/// Derive an independent stream seed from `(seed, iter, candidate)`.
///
/// The multi-candidate sampling trainer
/// (`SamplingConfig::candidates_per_iter > 1`) trains K candidate
/// models per iteration concurrently; giving every candidate its own
/// generator seeded by this function keeps the draw schedule (a) unique
/// per candidate — workers must not re-sample identical rows — and
/// (b) a pure function of the triple, so results are reproducible
/// regardless of which thread runs which candidate. Each coordinate is
/// pushed through a full SplitMix64 mix so adjacent triples land far
/// apart in seed space.
pub fn derive_stream_seed(seed: u64, iter: u64, candidate: u64) -> u64 {
    let s1 = SplitMix64::new(seed).next_u64();
    let s2 = SplitMix64::new(s1 ^ iter.wrapping_mul(0xA24B_AED4_963E_E407)).next_u64();
    SplitMix64::new(s2 ^ candidate.wrapping_mul(0x9FB2_1C65_1E98_DF25)).next_u64()
}

/// Xoshiro256++ PRNG. Implements the `rand_core` traits so it can be
/// swapped for any other generator in tests.
#[derive(Clone, Debug)]
pub struct Xoshiro256 {
    s: [u64; 4],
}

impl Xoshiro256 {
    /// Expand a 64-bit seed via SplitMix64 (the reference seeding recipe).
    pub fn new(seed: u64) -> Self {
        let mut sm = SplitMix64::new(seed);
        let mut s = [0u64; 4];
        for slot in &mut s {
            *slot = sm.next_u64();
        }
        // All-zero state is the one invalid state; SplitMix64 of any seed
        // cannot produce four zeros, but guard anyway.
        if s == [0, 0, 0, 0] {
            s[0] = 0x9E37_79B9_7F4A_7C15;
        }
        Xoshiro256 { s }
    }

    /// The 2^128-step jump, for carving independent parallel streams
    /// (used by the distributed workers).
    pub fn jump(&mut self) {
        const JUMP: [u64; 4] = [
            0x180e_c6d3_3cfd_0aba,
            0xd5a6_1266_f0c9_392c,
            0xa958_2618_e03f_c9aa,
            0x39ab_dc45_29b1_661c,
        ];
        let mut acc = [0u64; 4];
        for j in JUMP {
            for b in 0..64 {
                if (j >> b) & 1 == 1 {
                    for (a, s) in acc.iter_mut().zip(self.s.iter()) {
                        *a ^= s;
                    }
                }
                self.raw_next();
            }
        }
        self.s = acc;
    }

    /// Derive the `k`-th independent stream from this generator.
    pub fn stream(&self, k: u64) -> Xoshiro256 {
        let mut r = self.clone();
        for _ in 0..=k {
            r.jump();
        }
        r
    }

    #[inline]
    fn raw_next(&mut self) -> u64 {
        let result = self.s[0]
            .wrapping_add(self.s[3])
            .rotate_left(23)
            .wrapping_add(self.s[0]);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        result
    }

    // ---------------------------------------------------- distributions

    /// Uniform in `[0, 1)` with 53-bit resolution.
    #[inline]
    pub fn f64(&mut self) -> f64 {
        (self.raw_next() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    #[inline]
    pub fn range(&mut self, lo: f64, hi: f64) -> f64 {
        lo + (hi - lo) * self.f64()
    }

    /// Uniform integer in `[0, n)` (Lemire's multiply-shift, unbiased
    /// enough for sampling work at n << 2^64; exact rejection for the
    /// tail would change no experiment).
    #[inline]
    pub fn index(&mut self, n: usize) -> usize {
        debug_assert!(n > 0);
        ((self.raw_next() as u128 * n as u128) >> 64) as usize
    }

    /// Standard normal via Box–Muller (no caching of the second value —
    /// determinism under cloning beats saving one `cos`).
    #[inline]
    pub fn normal(&mut self) -> f64 {
        let u1 = self.f64().max(f64::MIN_POSITIVE);
        let u2 = self.f64();
        (-2.0 * u1.ln()).sqrt() * (2.0 * std::f64::consts::PI * u2).cos()
    }

    /// Normal with mean/std.
    #[inline]
    pub fn normal_ms(&mut self, mean: f64, std: f64) -> f64 {
        mean + std * self.normal()
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.index(i + 1);
            xs.swap(i, j);
        }
    }

    /// `k` indices drawn uniformly **with replacement** from `[0, n)` —
    /// the paper's SAMPLE(T, n) primitive (Algorithm 1 samples with
    /// replacement).
    pub fn sample_with_replacement(&mut self, n: usize, k: usize) -> Vec<usize> {
        (0..k).map(|_| self.index(n)).collect()
    }

    /// `k` distinct indices from `[0, n)` (partial Fisher–Yates on an
    /// index map; O(k) memory via a sparse swap table).
    pub fn sample_without_replacement(&mut self, n: usize, k: usize) -> Vec<usize> {
        assert!(k <= n, "cannot draw {k} distinct from {n}");
        let mut swaps: std::collections::HashMap<usize, usize> = Default::default();
        let mut out = Vec::with_capacity(k);
        for i in 0..k {
            let j = i + self.index(n - i);
            let vj = *swaps.get(&j).unwrap_or(&j);
            let vi = *swaps.get(&i).unwrap_or(&i);
            out.push(vj);
            swaps.insert(j, vi);
        }
        out
    }
}

impl RngCore for Xoshiro256 {
    fn next_u32(&mut self) -> u32 {
        (self.raw_next() >> 32) as u32
    }
    fn next_u64(&mut self) -> u64 {
        self.raw_next()
    }
    fn fill_bytes(&mut self, dest: &mut [u8]) {
        impls::fill_bytes_via_next(self, dest)
    }
    fn try_fill_bytes(&mut self, dest: &mut [u8]) -> Result<(), rand_core::Error> {
        self.fill_bytes(dest);
        Ok(())
    }
}

impl SeedableRng for Xoshiro256 {
    type Seed = [u8; 32];
    fn from_seed(seed: Self::Seed) -> Self {
        let mut s = [0u64; 4];
        for (i, chunk) in seed.chunks(8).enumerate() {
            s[i] = u64::from_le_bytes(chunk.try_into().unwrap());
        }
        if s == [0, 0, 0, 0] {
            s[0] = 1;
        }
        Xoshiro256 { s }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_across_clones() {
        let mut a = Xoshiro256::new(42);
        let mut b = Xoshiro256::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Xoshiro256::new(1);
        let mut b = Xoshiro256::new(2);
        assert_ne!(a.next_u64(), b.next_u64());
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut r = Xoshiro256::new(7);
        for _ in 0..10_000 {
            let x = r.f64();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_is_half() {
        let mut r = Xoshiro256::new(11);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.f64()).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.01, "mean={mean}");
    }

    #[test]
    fn normal_moments() {
        let mut r = Xoshiro256::new(13);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean={mean}");
        assert!((var - 1.0).abs() < 0.03, "var={var}");
    }

    #[test]
    fn index_bounds_and_coverage() {
        let mut r = Xoshiro256::new(17);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let i = r.index(10);
            assert!(i < 10);
            seen[i] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn without_replacement_distinct_and_complete() {
        let mut r = Xoshiro256::new(19);
        let got = r.sample_without_replacement(100, 100);
        let mut sorted = got.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn without_replacement_subset_distinct() {
        let mut r = Xoshiro256::new(23);
        for _ in 0..50 {
            let got = r.sample_without_replacement(50, 12);
            let set: std::collections::HashSet<_> = got.iter().collect();
            assert_eq!(set.len(), 12);
            assert!(got.iter().all(|&i| i < 50));
        }
    }

    #[test]
    fn with_replacement_in_range() {
        let mut r = Xoshiro256::new(29);
        let got = r.sample_with_replacement(5, 1000);
        assert_eq!(got.len(), 1000);
        assert!(got.iter().all(|&i| i < 5));
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Xoshiro256::new(31);
        let mut xs: Vec<u32> = (0..64).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..64).collect::<Vec<_>>());
        assert_ne!(xs, (0..64).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn jump_streams_are_decorrelated() {
        let base = Xoshiro256::new(5);
        let mut s0 = base.stream(0);
        let mut s1 = base.stream(1);
        let overlap = (0..1000).filter(|_| s0.next_u64() == s1.next_u64()).count();
        assert_eq!(overlap, 0);
    }

    #[test]
    fn derived_stream_seeds_unique_and_stable() {
        let mut seen = std::collections::HashSet::new();
        for seed in [0u64, 7, u64::MAX] {
            for iter in 0..20u64 {
                for cand in 0..20u64 {
                    assert!(
                        seen.insert(derive_stream_seed(seed, iter, cand)),
                        "collision at seed={seed} iter={iter} cand={cand}"
                    );
                }
            }
        }
        // pure function of the triple
        assert_eq!(derive_stream_seed(7, 3, 2), derive_stream_seed(7, 3, 2));
    }

    #[test]
    fn derived_streams_decorrelated() {
        let mut a = Xoshiro256::new(derive_stream_seed(42, 1, 0));
        let mut b = Xoshiro256::new(derive_stream_seed(42, 1, 1));
        let overlap = (0..1000).filter(|_| a.next_u64() == b.next_u64()).count();
        assert_eq!(overlap, 0);
    }

    #[test]
    fn seedable_from_seed_roundtrip() {
        let seed = [3u8; 32];
        let mut a = Xoshiro256::from_seed(seed);
        let mut b = Xoshiro256::from_seed(seed);
        assert_eq!(a.next_u64(), b.next_u64());
    }
}
