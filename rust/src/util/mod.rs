//! Self-contained substrates the vendored crate set does not provide:
//! RNG, JSON, statistics, a flat matrix, timing and table rendering.

pub mod json;
pub mod matrix;
pub mod rng;
pub mod stats;
pub mod tables;
pub mod timer;
