//! Self-contained substrates the vendored crate set does not provide:
//! RNG, JSON, hashing, statistics, a flat matrix, timing and table
//! rendering.

pub mod hash;
pub mod json;
pub mod matrix;
pub mod rng;
pub mod stats;
pub mod tables;
pub mod timer;
