//! ASCII table rendering for the bench harnesses: every bench binary
//! prints the same rows the paper's tables/figures report, via this
//! renderer (plus a CSV sink for plotting).

/// A simple left/right-aligned ASCII table.
#[derive(Clone, Debug, Default)]
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: impl Into<String>, headers: &[&str]) -> Self {
        Table {
            title: title.into(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: Vec<String>) -> &mut Self {
        assert_eq!(
            cells.len(),
            self.headers.len(),
            "row width mismatch in table '{}'",
            self.title
        );
        self.rows.push(cells);
        self
    }

    pub fn num_rows(&self) -> usize {
        self.rows.len()
    }

    pub fn render(&self) -> String {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for r in &self.rows {
            for (i, c) in r.iter().enumerate() {
                widths[i] = widths[i].max(c.len());
            }
        }
        let sep: String = {
            let mut s = String::from("+");
            for w in &widths {
                s.push_str(&"-".repeat(w + 2));
                s.push('+');
            }
            s
        };
        let fmt_row = |cells: &[String]| -> String {
            let mut s = String::from("|");
            for (c, w) in cells.iter().zip(&widths) {
                s.push(' ');
                s.push_str(c);
                s.push_str(&" ".repeat(w - c.len() + 1));
                s.push('|');
            }
            s
        };
        let mut out = String::new();
        if !self.title.is_empty() {
            out.push_str(&format!("== {} ==\n", self.title));
        }
        out.push_str(&sep);
        out.push('\n');
        out.push_str(&fmt_row(&self.headers));
        out.push('\n');
        out.push_str(&sep);
        out.push('\n');
        for r in &self.rows {
            out.push_str(&fmt_row(r));
            out.push('\n');
        }
        out.push_str(&sep);
        out
    }

    /// CSV form (headers + rows), for the plotting sinks in `results/`.
    pub fn to_csv(&self) -> String {
        let esc = |c: &str| {
            if c.contains(',') || c.contains('"') {
                format!("\"{}\"", c.replace('"', "\"\""))
            } else {
                c.to_string()
            }
        };
        let mut out = self
            .headers
            .iter()
            .map(|h| esc(h))
            .collect::<Vec<_>>()
            .join(",");
        out.push('\n');
        for r in &self.rows {
            out.push_str(&r.iter().map(|c| esc(c)).collect::<Vec<_>>().join(","));
            out.push('\n');
        }
        out
    }
}

/// Format helpers used by the benches.
pub fn f(x: f64, prec: usize) -> String {
    format!("{x:.prec$}")
}

pub fn i(x: usize) -> String {
    x.to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn renders_aligned() {
        let mut t = Table::new("Demo", &["Data", "#Obs", "R^2"]);
        t.row(vec!["Banana".into(), "11016".into(), "0.8789".into()]);
        t.row(vec!["Star".into(), "64000".into(), "0.9362".into()]);
        let out = t.render();
        assert!(out.contains("== Demo =="));
        assert!(out.contains("| Banana |"));
        let line_lens: Vec<usize> = out.lines().skip(1).map(|l| l.len()).collect();
        assert!(line_lens.windows(2).all(|w| w[0] == w[1]));
    }

    #[test]
    #[should_panic]
    fn row_width_mismatch_panics() {
        let mut t = Table::new("x", &["a", "b"]);
        t.row(vec!["only-one".into()]);
    }

    #[test]
    fn csv_escaping() {
        let mut t = Table::new("", &["name", "v"]);
        t.row(vec!["a,b".into(), "1".into()]);
        t.row(vec!["q\"q".into(), "2".into()]);
        let csv = t.to_csv();
        assert!(csv.contains("\"a,b\""));
        assert!(csv.contains("\"q\"\"q\""));
        assert!(csv.starts_with("name,v\n"));
    }

    #[test]
    fn fmt_helpers() {
        assert_eq!(f(0.87891, 4), "0.8789");
        assert_eq!(i(21), "21");
    }
}
