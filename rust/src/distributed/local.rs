//! In-process cluster: one thread per worker, channels for transport.
//! This is the default distributed mode (multi-machine topology, single
//! machine execution) and the reference the TCP transport is tested
//! against.

use crate::config::Method;
use crate::engine::{self, TrainContext, Trainer};
use crate::error::{Error, Result};
use crate::svdd::trainer::SvddParams;
use crate::util::matrix::Matrix;
use crate::util::rng::Xoshiro256;
use rand_core::RngCore;

use super::controller::{
    combine_with_mode, shard_with_shuffle, DistributedConfig, DistributedOutcome, RetryStats,
    WorkerReport,
};

/// Run the paper's distributed scheme with in-process workers.
pub fn train_local_cluster(
    data: &Matrix,
    params: &SvddParams,
    cfg: &DistributedConfig,
) -> Result<DistributedOutcome> {
    let shards = shard_with_shuffle(data, cfg.workers, cfg.shuffle_seed);
    // independent per-worker RNG streams via xoshiro jumps
    let base = Xoshiro256::new(cfg.seed);
    let worker_seeds: Vec<u64> = (0..shards.len())
        .map(|k| {
            let mut s = base.stream(k as u64);
            s.next_u64()
        })
        .collect();

    // every worker runs the sampling method through the same Trainer
    // registry entry all other consumers use — the shard trainer is a
    // generic `&dyn Trainer`, so a future per-shard method swap is a
    // registry lookup, not a new code path
    let shard_trainer = engine::trainer_for(Method::Sampling);
    let shard_trainer: &dyn Trainer = shard_trainer.as_ref();
    let results: Vec<Result<(Matrix, WorkerReport)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = shards
            .iter()
            .enumerate()
            .map(|(i, shard_data)| {
                let params = *params;
                let sampling = cfg.sampling;
                let seed = worker_seeds[i];
                scope.spawn(move || {
                    let ctx = TrainContext::new(params, sampling, seed);
                    let out = shard_trainer.train(&ctx, shard_data)?;
                    let report = WorkerReport {
                        worker: i,
                        shard_rows: shard_data.rows(),
                        sv_count: out.model.num_sv(),
                        iterations: out.iterations,
                        converged: out.converged,
                    };
                    Ok((out.model.support_vectors().clone(), report))
                })
            })
            .collect();
        handles
            .into_iter()
            .map(|h| {
                // surface a worker panic as a training error instead of
                // tearing down the whole process
                h.join().unwrap_or_else(|p| {
                    let msg = if let Some(s) = p.downcast_ref::<&str>() {
                        (*s).to_string()
                    } else if let Some(s) = p.downcast_ref::<String>() {
                        s.clone()
                    } else {
                        "unknown panic payload".to_string()
                    };
                    Err(Error::Distributed(format!("worker thread panicked: {msg}")))
                })
            })
            .collect()
    });

    let mut sv_sets = Vec::with_capacity(results.len());
    let mut reports = Vec::with_capacity(results.len());
    for r in results {
        let (sv, report) = r?;
        sv_sets.push(sv);
        reports.push(report);
    }
    let (model, union_rows, solver, combine_solves) =
        combine_with_mode(sv_sets, params, cfg.combine)?;
    Ok(DistributedOutcome {
        model,
        reports,
        union_rows,
        solver,
        combine_solves,
        retry: RetryStats::default(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{donut::TwoDonut, Generator};
    use crate::sampling::SamplingConfig;
    use crate::svdd::train;

    #[test]
    fn distributed_close_to_full() {
        let data = TwoDonut::default().generate(8000, 5);
        let params = SvddParams::gaussian(0.4, 0.001);
        let cfg = DistributedConfig {
            workers: 4,
            sampling: SamplingConfig { sample_size: 11, ..Default::default() },
            seed: 3,
            ..Default::default()
        };
        let dist = train_local_cluster(&data, &params, &cfg).unwrap();
        assert_eq!(dist.reports.len(), 4);
        assert!(dist.reports.iter().all(|r| r.shard_rows == 2000));
        let full = train(&data, &params).unwrap();
        let rel = (dist.model.r2() - full.r2()).abs() / full.r2();
        assert!(rel < 0.05, "R^2 gap {rel}");
    }

    #[test]
    fn single_worker_degenerates_to_sampling() {
        let data = TwoDonut::default().generate(3000, 6);
        let params = SvddParams::gaussian(0.4, 0.001);
        let cfg = DistributedConfig {
            workers: 1,
            sampling: SamplingConfig { sample_size: 11, ..Default::default() },
            seed: 4,
            ..Default::default()
        };
        let out = train_local_cluster(&data, &params, &cfg).unwrap();
        assert_eq!(out.reports.len(), 1);
        assert!(out.model.r2() > 0.0);
    }

    #[test]
    fn deterministic_given_seed() {
        let data = TwoDonut::default().generate(2000, 7);
        let params = SvddParams::gaussian(0.4, 0.001);
        let cfg = DistributedConfig {
            workers: 3,
            sampling: SamplingConfig { sample_size: 8, ..Default::default() },
            seed: 11,
            ..Default::default()
        };
        let a = train_local_cluster(&data, &params, &cfg).unwrap();
        let b = train_local_cluster(&data, &params, &cfg).unwrap();
        assert_eq!(a.model.r2(), b.model.r2());
        assert_eq!(a.union_rows, b.union_rows);
    }
}
