//! Deterministic fault injection for distributed workers.
//!
//! A [`FaultPlan`] describes *when* a worker misbehaves in terms of
//! counted protocol events — "die after serving 1 shard", "corrupt the
//! 2nd training reply" — never in terms of wall-clock time or
//! randomness, so a chaos test replays the exact same failure sequence
//! on every run. Plans are parsed from a compact `key=value` spec
//! (worker `--faults` flag or the [`FAULTS_ENV`] environment variable)
//! and enforced worker-side by a [`FaultInjector`] shared across all of
//! that worker's connections.
//!
//! Supported faults:
//!
//! | spec key       | effect                                                     |
//! |----------------|------------------------------------------------------------|
//! | `kill_after=K` | after K training replies the worker plays dead: every      |
//! |                | connection (including heartbeats) is dropped on sight;     |
//! |                | `kill_after=0` is dead-on-arrival                          |
//! | `delay_ms=D`   | sleep D ms before every training reply                     |
//! | `corrupt_at=N` | the Nth training reply (1-based) is sent as a garbage      |
//! |                | frame the controller cannot decode                         |
//! | `drop_at=N`    | the Nth training reply (1-based) is never sent — the       |
//! |                | connection is dropped instead                              |
//!
//! When `drop_at` and `corrupt_at` land on the same reply, the drop
//! wins. Faults only target the training path: handshake and stats
//! frames are left intact so liveness itself stays observable until the
//! kill threshold trips.

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::time::Duration;

use crate::error::{Error, Result};

/// Environment variable the worker binary reads a fault spec from when
/// no `--faults` flag is given.
pub const FAULTS_ENV: &str = "FASTSVDD_FAULTS";

/// A deterministic, count-based worker misbehaviour schedule.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// Play dead after this many training replies (0 = dead on arrival).
    pub kill_after: Option<u64>,
    /// Delay every training reply by this many milliseconds.
    pub delay_ms: u64,
    /// Corrupt the Nth training reply (1-based).
    pub corrupt_at: Option<u64>,
    /// Drop the connection instead of sending the Nth reply (1-based).
    pub drop_at: Option<u64>,
}

impl FaultPlan {
    /// Parse a `key=value[,key=value...]` spec. Unknown keys and
    /// malformed numbers are rejected; an empty spec is rejected too (a
    /// plan that does nothing is almost certainly a typo).
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut plan = FaultPlan::default();
        let mut any = false;
        for part in spec.split(',').map(str::trim).filter(|p| !p.is_empty()) {
            let (key, value) = part
                .split_once('=')
                .ok_or_else(|| Error::invalid(format!("fault spec '{part}': expected key=value")))?;
            let n: u64 = value
                .trim()
                .parse()
                .map_err(|_| Error::invalid(format!("fault spec '{part}': bad number")))?;
            match key.trim() {
                "kill_after" => plan.kill_after = Some(n),
                "delay_ms" => plan.delay_ms = n,
                "corrupt_at" => plan.corrupt_at = Some(n),
                "drop_at" => plan.drop_at = Some(n),
                k => return Err(Error::invalid(format!("fault spec: unknown key '{k}'"))),
            }
            any = true;
        }
        if !any {
            return Err(Error::invalid("fault spec is empty"));
        }
        Ok(plan)
    }

    /// Read a plan from [`FAULTS_ENV`]; `Ok(None)` when unset or blank.
    pub fn from_env() -> Result<Option<FaultPlan>> {
        match std::env::var(FAULTS_ENV) {
            Ok(spec) if !spec.trim().is_empty() => FaultPlan::parse(&spec).map(Some),
            _ => Ok(None),
        }
    }
}

/// What the worker should do with one training reply.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ReplyFault {
    /// Send the reply normally (after `delay`).
    Send { delay: Duration },
    /// Send a garbage frame instead (after `delay`).
    Corrupt { delay: Duration },
    /// Drop the connection without replying.
    Drop,
}

/// Shared, thread-safe enforcement of one worker's [`FaultPlan`] —
/// every connection consults the same reply counter, so the schedule is
/// global to the worker no matter how the controller spreads shards
/// over connections.
#[derive(Debug)]
pub struct FaultInjector {
    plan: FaultPlan,
    replies: AtomicU64,
    killed: AtomicBool,
}

impl FaultInjector {
    pub fn new(plan: FaultPlan) -> FaultInjector {
        FaultInjector {
            plan,
            replies: AtomicU64::new(0),
            killed: AtomicBool::new(plan.kill_after == Some(0)),
        }
    }

    /// An injector that never fires — the no-fault fast path.
    pub fn none() -> FaultInjector {
        FaultInjector::new(FaultPlan::default())
    }

    /// Has the kill threshold tripped? Dead workers drop every
    /// connection (heartbeats included) without a byte in response.
    pub fn killed(&self) -> bool {
        self.killed.load(Ordering::SeqCst)
    }

    /// Account one training reply (1-based sequence across all of the
    /// worker's connections) and return the fault to apply to it. Trips
    /// the kill switch once `kill_after` replies have been accounted —
    /// dropped and corrupted replies count, mirroring "kill worker k
    /// after shard j" over the shards the worker *attempted*.
    pub fn on_train_reply(&self) -> ReplyFault {
        let n = self.replies.fetch_add(1, Ordering::SeqCst) + 1;
        if let Some(k) = self.plan.kill_after {
            if n >= k {
                self.killed.store(true, Ordering::SeqCst);
            }
        }
        let delay = Duration::from_millis(self.plan.delay_ms);
        if self.plan.drop_at == Some(n) {
            ReplyFault::Drop
        } else if self.plan.corrupt_at == Some(n) {
            ReplyFault::Corrupt { delay }
        } else {
            ReplyFault::Send { delay }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parse_full_spec() {
        let plan = FaultPlan::parse("kill_after=2, delay_ms=50, corrupt_at=1, drop_at=3").unwrap();
        assert_eq!(
            plan,
            FaultPlan {
                kill_after: Some(2),
                delay_ms: 50,
                corrupt_at: Some(1),
                drop_at: Some(3),
            }
        );
    }

    #[test]
    fn parse_rejects_garbage() {
        assert!(FaultPlan::parse("").is_err());
        assert!(FaultPlan::parse("kill_after").is_err());
        assert!(FaultPlan::parse("kill_after=soon").is_err());
        assert!(FaultPlan::parse("explode=1").is_err());
    }

    #[test]
    fn injector_is_deterministic() {
        let plan = FaultPlan::parse("kill_after=3,corrupt_at=2,drop_at=1,delay_ms=7").unwrap();
        let run = |inj: FaultInjector| {
            let mut seq = Vec::new();
            for _ in 0..4 {
                seq.push((inj.on_train_reply(), inj.killed()));
            }
            seq
        };
        let a = run(FaultInjector::new(plan));
        let b = run(FaultInjector::new(plan));
        assert_eq!(a, b);
        // and the schedule is exactly what the spec says
        let d = Duration::from_millis(7);
        assert_eq!(a[0].0, ReplyFault::Drop);
        assert_eq!(a[1].0, ReplyFault::Corrupt { delay: d });
        assert_eq!(a[2].0, ReplyFault::Send { delay: d });
        assert!(!a[1].1, "alive before the kill threshold");
        assert!(a[2].1, "dead once kill_after replies served");
    }

    #[test]
    fn kill_after_zero_is_dead_on_arrival() {
        let inj = FaultInjector::new(FaultPlan::parse("kill_after=0").unwrap());
        assert!(inj.killed());
    }

    #[test]
    fn noop_injector_never_fires() {
        let inj = FaultInjector::none();
        for _ in 0..10 {
            assert_eq!(inj.on_train_reply(), ReplyFault::Send { delay: Duration::ZERO });
        }
        assert!(!inj.killed());
    }
}
