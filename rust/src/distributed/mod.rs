//! Distributed training (paper section III-1, Fig 2).
//!
//! Topology: the controller shards the training data over `p` workers;
//! each worker runs the sampling method (Algorithm 1) on its shard and
//! promotes its master SV set `SV_i*` to the controller; the controller
//! unions all worker SV sets into `S'` and computes one final SVDD on
//! it.
//!
//! Two transports share one message protocol ([`message`]):
//! - [`local`] — in-process workers (threads + channels), the default;
//! - [`tcp`] — a length-prefixed binary protocol over TCP for actual
//!   multi-process clusters (no tokio in the vendored crate set, so
//!   std::net + a thread per connection).

pub mod controller;
pub mod local;
pub mod message;
pub mod tcp;

pub use controller::{DistributedConfig, DistributedOutcome};
pub use local::train_local_cluster;
pub use tcp::{cluster_stats, train_tcp_cluster, ClusterStats, WorkerServer};
