//! Distributed training (paper section III-1, Fig 2).
//!
//! Topology: the controller shards the training data over `p` workers;
//! each worker runs the sampling method (Algorithm 1) on its shard and
//! promotes its master SV set `SV_i*` to the controller; the controller
//! unions all worker SV sets into `S'` and computes one final SVDD on
//! it.
//!
//! Two transports share one message protocol ([`message`]):
//! - [`local`] — in-process workers (threads + channels), the default;
//! - [`tcp`] — a length-prefixed binary protocol over TCP for actual
//!   multi-process clusters (no tokio in the vendored crate set, so
//!   std::net + a thread per connection).
//!
//! The TCP transport is fault tolerant: per-attempt socket deadlines,
//! `Heartbeat` liveness probes, a healthy → suspect → dead worker state
//! machine, bounded shard retry with exponential backoff + jitter and
//! reassignment to surviving workers, and graceful degradation to local
//! execution when the live set shrinks below `min_workers` (see
//! [`tcp`]). Worker misbehaviour is reproducible on demand through the
//! deterministic fault-injection layer in [`faults`].

pub mod controller;
pub mod faults;
pub mod local;
pub mod message;
pub mod tcp;

pub use controller::{CombineMode, DistributedConfig, DistributedOutcome, RetryStats};
pub use faults::{FaultInjector, FaultPlan};
pub use local::train_local_cluster;
pub use tcp::{
    cluster_stats, cluster_stats_with_timeout, train_tcp_cluster, train_tcp_cluster_stream,
    ClusterStats, WorkerServer, WorkerState,
};
