//! Wire protocol for the distributed cluster: versioned, length-prefixed
//! binary frames with explicit little-endian scalar encoding. Shared by
//! the TCP transport (serialized) and unit-tested independently of any
//! socket.

use std::io::{Read, Write};

use crate::error::{Error, Result};
use crate::sampling::SamplingConfig;
use crate::svdd::trainer::SvddParams;
use crate::svdd::Kernel;
use crate::util::matrix::Matrix;

/// Protocol version — bumped on any frame-layout or vocabulary change.
/// v2 added the model-lifecycle frames (`ModelInfoRequest`/`ModelInfo`/
/// `SwapModel`/`SwapAck`) and the metrics frames (`StatsRequest`/
/// `StatsReply`); v3 added the serving-edge frames (`ScoreRequestV2`/
/// `ScoreReplyV2`/`Overloaded`); v4 added the liveness frames
/// (`Heartbeat`/`HeartbeatAck`) used by the fault-tolerant controller.
/// Every older frame is encoded identically, so newer servers still
/// speak to older clients (see [`negotiate`]) — a session negotiated
/// down must never carry a frame whose [`Message::min_version`] exceeds
/// the session version.
pub const PROTOCOL_VERSION: u32 = 4;

/// Oldest peer version this build still understands.
pub const MIN_PROTOCOL_VERSION: u32 = 1;

/// Version negotiation at Hello time: the session runs at the lower of
/// the two versions, provided the peer is not older than
/// [`MIN_PROTOCOL_VERSION`]. `None` means the peer must be rejected.
pub fn negotiate(peer_version: u32) -> Option<u32> {
    if peer_version < MIN_PROTOCOL_VERSION {
        None
    } else {
        Some(peer_version.min(PROTOCOL_VERSION))
    }
}

/// Frames exchanged between controller and worker.
#[derive(Clone, Debug, PartialEq)]
pub enum Message {
    /// Controller -> worker handshake.
    Hello { version: u32 },
    /// Worker -> controller handshake ack.
    HelloAck { version: u32 },
    /// Controller -> worker: run the sampling method on this shard.
    Train {
        shard: Matrix,
        bw: f64,
        outlier_fraction: f64,
        sample_size: u32,
        max_iter: u32,
        seed: u64,
    },
    /// Worker -> controller: the shard's master SV set + stats.
    TrainDone {
        sv: Matrix,
        r2: f64,
        iterations: u32,
        converged: bool,
    },
    /// Worker -> controller: failure report.
    TrainFailed { reason: String },
    /// Controller -> worker: shut down cleanly.
    Shutdown,
    /// Client -> scoring server: score these observations.
    ScoreRequest { rows: Matrix },
    /// Scoring server -> client: dist^2 per row + the model threshold.
    /// `r2` is always the threshold of the model that scored *this*
    /// batch, so a reply is internally consistent across a hot-swap.
    ScoreReply { dist2: Vec<f64>, r2: f64 },
    /// Client -> scoring server (v2): describe the active model.
    ModelInfoRequest,
    /// Scoring server -> client (v2): active model identity + stats.
    /// `version` is the content-addressed id ([`content_id`]); `epoch`
    /// counts hot-swaps since the server started.
    ///
    /// [`content_id`]: crate::svdd::model::SvddModel::content_id
    ModelInfo {
        version: String,
        r2: f64,
        num_sv: u32,
        dim: u32,
        epoch: u64,
    },
    /// Client -> scoring server (v2): hot-swap the active model. The
    /// payload is the model's JSON (`SvddModel::to_json`) — in-flight
    /// batches finish on the old model, later batches use the new one.
    SwapModel { model_json: String },
    /// Scoring server -> client (v2): swap verdict. On rejection
    /// (`swapped == false`) `epoch`/`r2` describe the unchanged active
    /// model and `reason` says why.
    SwapAck {
        epoch: u64,
        swapped: bool,
        r2: f64,
        reason: String,
    },
    /// Client/controller -> server (v2): pull the peer's metrics.
    StatsRequest,
    /// Server -> client/controller (v2): metrics snapshot. `text` is
    /// the Prometheus exposition ([`render_prometheus`]) for humans and
    /// scrapers; `counters` is the exact named-counter snapshot
    /// ([`snapshot`]) so a controller can [`aggregate`] cluster-wide
    /// totals without parsing text.
    ///
    /// [`render_prometheus`]: crate::metrics::Metrics::render_prometheus
    /// [`snapshot`]: crate::metrics::Metrics::snapshot
    /// [`aggregate`]: crate::metrics::aggregate
    StatsReply {
        text: String,
        counters: Vec<(String, u64)>,
    },
    /// Client -> scoring server (v3): score these observations and
    /// reply with the full [`Message::ScoreReplyV2`] provenance. The
    /// rows are encoded exactly like [`Message::ScoreRequest`]; only
    /// the reply shape differs.
    ScoreRequestV2 { rows: Matrix },
    /// Scoring server -> client (v3): dist^2 per row plus the scoring
    /// model's full identity — threshold, hot-swap epoch and
    /// content-addressed id — so a reply is self-describing across
    /// swaps (the wire form of [`crate::scoring::ScoreReply`]).
    ScoreReplyV2 {
        dist2: Vec<f64>,
        r2: f64,
        epoch: u64,
        model_id: String,
    },
    /// Scoring server -> client (v3): the request was shed under load
    /// (bounded queue / in-flight cap). The connection survives; the
    /// client should back off and retry.
    Overloaded { reason: String },
    /// Controller -> worker (v4): liveness probe. Sent on a fresh
    /// short-timeout connection while a training connection is quiet,
    /// so the controller can tell "still computing" from "dead".
    Heartbeat,
    /// Worker -> controller (v4): liveness ack. A worker that has been
    /// fault-injected dead drops the connection instead of acking.
    HeartbeatAck,
}

impl Message {
    /// Build a Train message from typed params.
    pub fn train(shard: Matrix, params: &SvddParams, cfg: &SamplingConfig, seed: u64) -> Message {
        Message::Train {
            shard,
            bw: params.kernel.bw().unwrap_or(1.0),
            outlier_fraction: params.outlier_fraction,
            sample_size: cfg.sample_size as u32,
            max_iter: cfg.max_iter as u32,
            seed,
        }
    }

    /// Recover typed params from a Train message.
    pub fn train_params(bw: f64, f: f64) -> SvddParams {
        SvddParams {
            kernel: Kernel::gaussian(bw),
            ..SvddParams { outlier_fraction: f, ..Default::default() }
        }
    }

    // ---------------------------------------------------------- codec

    fn tag(&self) -> u8 {
        match self {
            Message::Hello { .. } => 0,
            Message::HelloAck { .. } => 1,
            Message::Train { .. } => 2,
            Message::TrainDone { .. } => 3,
            Message::TrainFailed { .. } => 4,
            Message::Shutdown => 5,
            Message::ScoreRequest { .. } => 6,
            Message::ScoreReply { .. } => 7,
            Message::ModelInfoRequest => 8,
            Message::ModelInfo { .. } => 9,
            Message::SwapModel { .. } => 10,
            Message::SwapAck { .. } => 11,
            Message::StatsRequest => 12,
            Message::StatsReply { .. } => 13,
            Message::ScoreRequestV2 { .. } => 14,
            Message::ScoreReplyV2 { .. } => 15,
            Message::Overloaded { .. } => 16,
            Message::Heartbeat => 17,
            Message::HeartbeatAck => 18,
        }
    }

    /// Lowest protocol version whose vocabulary includes this frame. A
    /// session negotiated to version `v` must never carry a frame with
    /// `min_version() > v` in either direction — servers drop such
    /// connections rather than answer with frames the peer cannot
    /// decode.
    pub fn min_version(&self) -> u32 {
        match self.tag() {
            0..=7 => 1,
            8..=13 => 2,
            14..=16 => 3,
            _ => 4,
        }
    }

    /// Is this frame beyond the v1 vocabulary? Sessions negotiated down
    /// to v1 must never see these tags in either direction.
    pub fn requires_v2(&self) -> bool {
        self.min_version() >= 2
    }

    /// Serialize to a byte buffer (without the outer length prefix).
    pub fn encode(&self) -> Vec<u8> {
        let mut b = vec![self.tag()];
        match self {
            Message::Hello { version } | Message::HelloAck { version } => {
                put_u32(&mut b, *version);
            }
            Message::Train { shard, bw, outlier_fraction, sample_size, max_iter, seed } => {
                put_matrix(&mut b, shard);
                put_f64(&mut b, *bw);
                put_f64(&mut b, *outlier_fraction);
                put_u32(&mut b, *sample_size);
                put_u32(&mut b, *max_iter);
                put_u64(&mut b, *seed);
            }
            Message::TrainDone { sv, r2, iterations, converged } => {
                put_matrix(&mut b, sv);
                put_f64(&mut b, *r2);
                put_u32(&mut b, *iterations);
                b.push(*converged as u8);
            }
            Message::TrainFailed { reason } => {
                put_bytes(&mut b, reason.as_bytes());
            }
            Message::Shutdown => {}
            Message::ScoreRequest { rows } => {
                put_matrix(&mut b, rows);
            }
            Message::ScoreReply { dist2, r2 } => {
                put_u32(&mut b, dist2.len() as u32);
                for &v in dist2 {
                    put_f64(&mut b, v);
                }
                put_f64(&mut b, *r2);
            }
            Message::ModelInfoRequest => {}
            Message::ModelInfo { version, r2, num_sv, dim, epoch } => {
                put_bytes(&mut b, version.as_bytes());
                put_f64(&mut b, *r2);
                put_u32(&mut b, *num_sv);
                put_u32(&mut b, *dim);
                put_u64(&mut b, *epoch);
            }
            Message::SwapModel { model_json } => {
                put_bytes(&mut b, model_json.as_bytes());
            }
            Message::SwapAck { epoch, swapped, r2, reason } => {
                put_u64(&mut b, *epoch);
                b.push(*swapped as u8);
                put_f64(&mut b, *r2);
                put_bytes(&mut b, reason.as_bytes());
            }
            Message::StatsRequest => {}
            Message::StatsReply { text, counters } => {
                put_bytes(&mut b, text.as_bytes());
                put_u32(&mut b, counters.len() as u32);
                for (k, v) in counters {
                    put_bytes(&mut b, k.as_bytes());
                    put_u64(&mut b, *v);
                }
            }
            Message::ScoreRequestV2 { rows } => {
                put_matrix(&mut b, rows);
            }
            Message::ScoreReplyV2 { dist2, r2, epoch, model_id } => {
                put_u32(&mut b, dist2.len() as u32);
                for &v in dist2 {
                    put_f64(&mut b, v);
                }
                put_f64(&mut b, *r2);
                put_u64(&mut b, *epoch);
                put_bytes(&mut b, model_id.as_bytes());
            }
            Message::Overloaded { reason } => {
                put_bytes(&mut b, reason.as_bytes());
            }
            Message::Heartbeat => {}
            Message::HeartbeatAck => {}
        }
        b
    }

    /// Inverse of [`Message::encode`].
    pub fn decode(buf: &[u8]) -> Result<Message> {
        let mut c = Cursor { buf, pos: 0 };
        let tag = c.u8()?;
        let msg = match tag {
            0 => Message::Hello { version: c.u32()? },
            1 => Message::HelloAck { version: c.u32()? },
            2 => Message::Train {
                shard: c.matrix()?,
                bw: c.f64()?,
                outlier_fraction: c.f64()?,
                sample_size: c.u32()?,
                max_iter: c.u32()?,
                seed: c.u64()?,
            },
            3 => Message::TrainDone {
                sv: c.matrix()?,
                r2: c.f64()?,
                iterations: c.u32()?,
                converged: c.u8()? != 0,
            },
            4 => Message::TrainFailed {
                reason: String::from_utf8_lossy(&c.bytes()?).into_owned(),
            },
            5 => Message::Shutdown,
            6 => Message::ScoreRequest { rows: c.matrix()? },
            7 => {
                let n = c.u32()? as usize;
                if n > MAX_FRAME / 8 {
                    return Err(Error::Distributed(format!("reply too large: {n}")));
                }
                let mut dist2 = Vec::with_capacity(n);
                for _ in 0..n {
                    dist2.push(c.f64()?);
                }
                Message::ScoreReply { dist2, r2: c.f64()? }
            }
            8 => Message::ModelInfoRequest,
            9 => Message::ModelInfo {
                version: String::from_utf8_lossy(&c.bytes()?).into_owned(),
                r2: c.f64()?,
                num_sv: c.u32()?,
                dim: c.u32()?,
                epoch: c.u64()?,
            },
            10 => Message::SwapModel {
                model_json: String::from_utf8_lossy(&c.bytes()?).into_owned(),
            },
            11 => Message::SwapAck {
                epoch: c.u64()?,
                swapped: c.u8()? != 0,
                r2: c.f64()?,
                reason: String::from_utf8_lossy(&c.bytes()?).into_owned(),
            },
            12 => Message::StatsRequest,
            13 => {
                let text = String::from_utf8_lossy(&c.bytes()?).into_owned();
                let n = c.u32()? as usize;
                if n > MAX_FRAME / 12 {
                    return Err(Error::Distributed(format!("stats reply too large: {n}")));
                }
                let mut counters = Vec::with_capacity(n);
                for _ in 0..n {
                    let k = String::from_utf8_lossy(&c.bytes()?).into_owned();
                    counters.push((k, c.u64()?));
                }
                Message::StatsReply { text, counters }
            }
            14 => Message::ScoreRequestV2 { rows: c.matrix()? },
            15 => {
                let n = c.u32()? as usize;
                if n > MAX_FRAME / 8 {
                    return Err(Error::Distributed(format!("reply too large: {n}")));
                }
                let mut dist2 = Vec::with_capacity(n);
                for _ in 0..n {
                    dist2.push(c.f64()?);
                }
                Message::ScoreReplyV2 {
                    dist2,
                    r2: c.f64()?,
                    epoch: c.u64()?,
                    model_id: String::from_utf8_lossy(&c.bytes()?).into_owned(),
                }
            }
            16 => Message::Overloaded {
                reason: String::from_utf8_lossy(&c.bytes()?).into_owned(),
            },
            17 => Message::Heartbeat,
            18 => Message::HeartbeatAck,
            t => return Err(Error::Distributed(format!("unknown tag {t}"))),
        };
        if c.pos != buf.len() {
            return Err(Error::Distributed(format!(
                "{} trailing bytes after tag {tag}",
                buf.len() - c.pos
            )));
        }
        Ok(msg)
    }

    /// Write `self` as a length-prefixed frame.
    pub fn write_to(&self, w: &mut impl Write) -> Result<()> {
        let body = self.encode();
        if body.len() > MAX_FRAME {
            return Err(Error::Distributed(format!("frame too large: {}", body.len())));
        }
        w.write_all(&(body.len() as u32).to_le_bytes())?;
        w.write_all(&body)?;
        w.flush()?;
        Ok(())
    }

    /// Read one length-prefixed frame.
    pub fn read_from(r: &mut impl Read) -> Result<Message> {
        let mut len_bytes = [0u8; 4];
        r.read_exact(&mut len_bytes)?;
        Message::read_after_len(len_bytes, r)
    }

    /// Finish reading a frame whose 4-byte length prefix was already
    /// consumed — the scoring server peeks those bytes first to tell
    /// native frames from HTTP request lines on the shared listener.
    pub fn read_after_len(len_bytes: [u8; 4], r: &mut impl Read) -> Result<Message> {
        let len = u32::from_le_bytes(len_bytes) as usize;
        if len > MAX_FRAME {
            return Err(Error::Distributed(format!("incoming frame too large: {len}")));
        }
        let mut body = vec![0u8; len];
        r.read_exact(&mut body)?;
        Message::decode(&body)
    }
}

/// 256 MiB frame cap (a 1M x 16 f64 shard is 128 MiB; shards beyond the
/// cap should be split across more workers).
pub const MAX_FRAME: usize = 256 << 20;

// -------------------------------------------------------- primitives

fn put_u32(b: &mut Vec<u8>, v: u32) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_u64(b: &mut Vec<u8>, v: u64) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_f64(b: &mut Vec<u8>, v: f64) {
    b.extend_from_slice(&v.to_le_bytes());
}

fn put_bytes(b: &mut Vec<u8>, v: &[u8]) {
    put_u32(b, v.len() as u32);
    b.extend_from_slice(v);
}

fn put_matrix(b: &mut Vec<u8>, m: &Matrix) {
    put_u32(b, m.rows() as u32);
    put_u32(b, m.cols() as u32);
    for &v in m.as_slice() {
        put_f64(b, v);
    }
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            return Err(Error::Distributed("truncated frame".into()));
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u8(&mut self) -> Result<u8> {
        Ok(self.take(1)?[0])
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn u64(&mut self) -> Result<u64> {
        Ok(u64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn f64(&mut self) -> Result<f64> {
        Ok(f64::from_le_bytes(self.take(8)?.try_into().unwrap()))
    }

    fn bytes(&mut self) -> Result<Vec<u8>> {
        let n = self.u32()? as usize;
        Ok(self.take(n)?.to_vec())
    }

    fn matrix(&mut self) -> Result<Matrix> {
        let rows = self.u32()? as usize;
        let cols = self.u32()? as usize;
        if rows.saturating_mul(cols) > MAX_FRAME / 8 {
            return Err(Error::Distributed(format!("matrix too large: {rows}x{cols}")));
        }
        let mut data = Vec::with_capacity(rows * cols);
        for _ in 0..rows * cols {
            data.push(self.f64()?);
        }
        Matrix::from_vec(data, rows, cols)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample_matrix() -> Matrix {
        Matrix::from_rows(&[vec![1.5, -2.0], vec![0.0, 3.25]]).unwrap()
    }

    #[test]
    fn roundtrip_all_variants() {
        let msgs = vec![
            Message::Hello { version: 1 },
            Message::HelloAck { version: 7 },
            Message::Train {
                shard: sample_matrix(),
                bw: 0.8,
                outlier_fraction: 0.001,
                sample_size: 11,
                max_iter: 500,
                seed: 0xDEADBEEF,
            },
            Message::TrainDone {
                sv: sample_matrix(),
                r2: 0.93,
                iterations: 42,
                converged: true,
            },
            Message::TrainFailed { reason: "boom 💥".into() },
            Message::Shutdown,
            Message::ScoreRequest { rows: sample_matrix() },
            Message::ScoreReply { dist2: vec![0.25, 1.5, -0.0], r2: 0.9 },
            Message::ModelInfoRequest,
            Message::ModelInfo {
                version: "v-00f3a9c2deadbeef".into(),
                r2: 0.87,
                num_sv: 23,
                dim: 41,
                epoch: 5,
            },
            Message::SwapModel { model_json: r#"{"format":"fastsvdd-model-v1"}"#.into() },
            Message::SwapAck {
                epoch: 6,
                swapped: true,
                r2: 0.91,
                reason: String::new(),
            },
            Message::SwapAck {
                epoch: 6,
                swapped: false,
                r2: 0.91,
                reason: "dim mismatch 🙅".into(),
            },
            Message::StatsRequest,
            Message::StatsReply {
                text: "# HELP fastsvdd_rows_scored_total rows\n".into(),
                counters: vec![("rows_scored".into(), 128), ("batches_scored".into(), 2)],
            },
            Message::StatsReply { text: String::new(), counters: vec![] },
            Message::ScoreRequestV2 { rows: sample_matrix() },
            Message::ScoreReplyV2 {
                dist2: vec![0.5, -1.25],
                r2: 0.88,
                epoch: 9,
                model_id: "v-00f3a9c2deadbeef".into(),
            },
            Message::Overloaded { reason: "scoring queue full".into() },
            Message::Heartbeat,
            Message::HeartbeatAck,
        ];
        for m in msgs {
            let enc = m.encode();
            let dec = Message::decode(&enc).unwrap();
            assert_eq!(m, dec);
        }
    }

    #[test]
    fn framed_roundtrip_via_buffer() {
        let m = Message::TrainDone {
            sv: sample_matrix(),
            r2: 0.5,
            iterations: 3,
            converged: false,
        };
        let mut buf = Vec::new();
        m.write_to(&mut buf).unwrap();
        let mut cursor = std::io::Cursor::new(buf);
        let back = Message::read_from(&mut cursor).unwrap();
        assert_eq!(m, back);
    }

    #[test]
    fn multiple_frames_stream() {
        let mut buf = Vec::new();
        Message::Hello { version: 1 }.write_to(&mut buf).unwrap();
        Message::Shutdown.write_to(&mut buf).unwrap();
        let mut cursor = std::io::Cursor::new(buf);
        assert_eq!(
            Message::read_from(&mut cursor).unwrap(),
            Message::Hello { version: 1 }
        );
        assert_eq!(Message::read_from(&mut cursor).unwrap(), Message::Shutdown);
    }

    #[test]
    fn truncated_frame_rejected() {
        let m = Message::Hello { version: 1 };
        let enc = m.encode();
        assert!(Message::decode(&enc[..enc.len() - 1]).is_err());
    }

    #[test]
    fn trailing_bytes_rejected() {
        let mut enc = Message::Shutdown.encode();
        enc.push(0);
        assert!(Message::decode(&enc).is_err());
    }

    #[test]
    fn unknown_tag_rejected() {
        assert!(Message::decode(&[99]).is_err());
    }

    #[test]
    fn negotiation_is_backward_compatible() {
        // a v1 peer keeps working at v1
        assert_eq!(negotiate(1), Some(1));
        // same-version peers run at the current version
        assert_eq!(negotiate(PROTOCOL_VERSION), Some(PROTOCOL_VERSION));
        // a newer peer is capped at our version, never rejected
        assert_eq!(negotiate(PROTOCOL_VERSION + 5), Some(PROTOCOL_VERSION));
        // prehistoric peers are rejected
        assert_eq!(negotiate(MIN_PROTOCOL_VERSION.saturating_sub(1)), None);
    }

    #[test]
    fn v2_vocabulary_is_exactly_the_lifecycle_and_stats_frames() {
        assert!(!Message::Hello { version: 1 }.requires_v2());
        assert!(!Message::Shutdown.requires_v2());
        assert!(!Message::ScoreReply { dist2: vec![], r2: 0.0 }.requires_v2());
        assert!(Message::ModelInfoRequest.requires_v2());
        assert!(Message::StatsRequest.requires_v2());
        assert!(Message::StatsReply { text: String::new(), counters: vec![] }.requires_v2());
    }

    #[test]
    fn min_version_partitions_the_vocabulary() {
        assert_eq!(Message::Hello { version: 1 }.min_version(), 1);
        assert_eq!(Message::ScoreRequest { rows: sample_matrix() }.min_version(), 1);
        assert_eq!(Message::ModelInfoRequest.min_version(), 2);
        assert_eq!(
            Message::StatsReply { text: String::new(), counters: vec![] }.min_version(),
            2
        );
        // the serving-edge frames are v3-only: a v2 session must never
        // carry them (older builds cannot decode tags 14-16)
        assert_eq!(Message::ScoreRequestV2 { rows: sample_matrix() }.min_version(), 3);
        assert_eq!(
            Message::ScoreReplyV2 {
                dist2: vec![],
                r2: 0.0,
                epoch: 0,
                model_id: String::new()
            }
            .min_version(),
            3
        );
        assert_eq!(Message::Overloaded { reason: String::new() }.min_version(), 3);
        // the liveness frames are v4-only: a v3 session must never
        // carry them (older builds cannot decode tags 17-18)
        assert_eq!(Message::Heartbeat.min_version(), 4);
        assert_eq!(Message::HeartbeatAck.min_version(), 4);
        // min_version is consistent with the v2 predicate
        assert!(Message::Overloaded { reason: String::new() }.requires_v2());
        assert!(Message::Heartbeat.requires_v2());
    }

    #[test]
    fn read_after_len_matches_read_from() {
        let m = Message::StatsReply {
            text: "x".into(),
            counters: vec![("solver_calls".into(), 3)],
        };
        let mut buf = Vec::new();
        m.write_to(&mut buf).unwrap();
        let len_bytes: [u8; 4] = buf[..4].try_into().unwrap();
        let mut rest = std::io::Cursor::new(&buf[4..]);
        assert_eq!(Message::read_after_len(len_bytes, &mut rest).unwrap(), m);
    }

    #[test]
    fn oversized_declared_matrix_rejected() {
        // tag=2 (Train) with absurd rows*cols
        let mut b = vec![2u8];
        b.extend_from_slice(&u32::MAX.to_le_bytes());
        b.extend_from_slice(&u32::MAX.to_le_bytes());
        assert!(Message::decode(&b).is_err());
    }
}
