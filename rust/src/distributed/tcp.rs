//! TCP transport: a worker daemon (`fastsvdd worker --listen ...`) and
//! a controller client, speaking the [`super::message`] protocol over
//! length-prefixed frames. One thread per accepted connection; the
//! handshake pins the protocol version.
//!
//! Each worker keeps a [`Metrics`] registry of its solver telemetry; a
//! v2 peer pulls it with [`Message::StatsRequest`], and
//! [`cluster_stats`] fans that request across a worker fleet and
//! [`crate::metrics::aggregate`]s the exact counters cluster-wide.

use std::net::{TcpListener, TcpStream, ToSocketAddrs};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

use crate::error::{Error, Result};
use crate::metrics::Metrics;
use crate::sampling::{SamplingConfig, SamplingTrainer};
use crate::svdd::trainer::SvddParams;
use crate::svdd::Kernel;
use crate::util::matrix::Matrix;
use crate::util::rng::Xoshiro256;
use rand_core::RngCore;

use super::controller::{
    combine_detailed, shard_with_shuffle, DistributedConfig, DistributedOutcome, WorkerReport,
};
use super::message::{negotiate, Message, PROTOCOL_VERSION};

/// A running worker server (owns its listener thread).
pub struct WorkerServer {
    addr: std::net::SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
    metrics: Arc<Metrics>,
}

impl WorkerServer {
    /// Bind `addr` (e.g. "127.0.0.1:0") and serve train requests until
    /// [`WorkerServer::stop`] or process exit.
    pub fn spawn(addr: impl ToSocketAddrs) -> Result<WorkerServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let metrics = Arc::new(Metrics::new());
        let accept_metrics = metrics.clone();
        let handle = std::thread::spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        stream.set_nonblocking(false).ok();
                        let stop3 = stop2.clone();
                        let mx = accept_metrics.clone();
                        std::thread::spawn(move || {
                            let _ = handle_connection(stream, &stop3, &mx);
                        });
                    }
                    Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                        std::thread::sleep(std::time::Duration::from_millis(10));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(WorkerServer { addr: local, stop, handle: Some(handle), metrics })
    }

    pub fn addr(&self) -> std::net::SocketAddr {
        self.addr
    }

    /// The worker's metrics registry (shard-train telemetry).
    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Ask the accept loop to exit (in-flight connections finish).
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            h.join().ok();
        }
    }
}

impl Drop for WorkerServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn handle_connection(
    mut stream: TcpStream,
    stop: &AtomicBool,
    metrics: &Metrics,
) -> Result<()> {
    // handshake
    let session_version = match Message::read_from(&mut stream)? {
        Message::Hello { version } => match negotiate(version) {
            Some(v) => {
                Message::HelloAck { version: v }.write_to(&mut stream)?;
                v
            }
            None => {
                Message::TrainFailed {
                    reason: format!("peer version {version} too old (< min supported)"),
                }
                .write_to(&mut stream)?;
                return Err(Error::Distributed("handshake version mismatch".into()));
            }
        },
        other => {
            return Err(Error::Distributed(format!("expected Hello, got {other:?}")));
        }
    };
    // serve
    while !stop.load(Ordering::Relaxed) {
        let msg = match Message::read_from(&mut stream) {
            Ok(m) => m,
            Err(_) => break, // peer went away
        };
        // never accept a frame the negotiated session version cannot carry
        if msg.min_version() > session_version {
            return Err(Error::Distributed(format!(
                "v{} frame on a v{session_version} session: {msg:?}",
                msg.min_version()
            )));
        }
        match msg {
            Message::Train { shard, bw, outlier_fraction, sample_size, max_iter, seed } => {
                let params = SvddParams {
                    kernel: Kernel::gaussian(bw),
                    outlier_fraction,
                    ..Default::default()
                };
                let cfg = SamplingConfig {
                    sample_size: sample_size as usize,
                    max_iter: max_iter as usize,
                    ..Default::default()
                };
                let reply = match SamplingTrainer::new(params, cfg).train(&shard, seed) {
                    Ok(out) => {
                        metrics.record_training(out.solver_calls, out.iterations, &out.solver);
                        Message::TrainDone {
                            sv: out.model.support_vectors().clone(),
                            r2: out.model.r2(),
                            iterations: out.iterations as u32,
                            converged: out.converged,
                        }
                    }
                    Err(e) => Message::TrainFailed { reason: e.to_string() },
                };
                reply.write_to(&mut stream)?;
            }
            Message::StatsRequest => {
                Message::StatsReply {
                    text: metrics.render_prometheus(),
                    counters: metrics.snapshot(),
                }
                .write_to(&mut stream)?;
            }
            Message::Shutdown => break,
            other => {
                return Err(Error::Distributed(format!("unexpected {other:?}")));
            }
        }
    }
    Ok(())
}

/// Controller over TCP workers: shard the data, send one Train per
/// worker (round-robin over addresses), gather SV sets, combine.
pub fn train_tcp_cluster(
    data: &Matrix,
    params: &SvddParams,
    cfg: &DistributedConfig,
    addrs: &[std::net::SocketAddr],
) -> Result<DistributedOutcome> {
    if addrs.is_empty() {
        return Err(Error::Distributed("no worker addresses".into()));
    }
    let shards = shard_with_shuffle(data, cfg.workers, cfg.shuffle_seed);
    let base = Xoshiro256::new(cfg.seed);

    let results: Vec<Result<(Matrix, WorkerReport)>> = std::thread::scope(|scope| {
        let handles: Vec<_> = shards
            .into_iter()
            .enumerate()
            .map(|(i, shard_data)| {
                let addr = addrs[i % addrs.len()];
                let params = *params;
                let sampling = cfg.sampling;
                let mut rng = base.stream(i as u64);
                let seed = rng.next_u64();
                scope.spawn(move || -> Result<(Matrix, WorkerReport)> {
                    let mut stream = TcpStream::connect(addr)?;
                    Message::Hello { version: PROTOCOL_VERSION }.write_to(&mut stream)?;
                    match Message::read_from(&mut stream)? {
                        Message::HelloAck { version } if negotiate(version).is_some() => {}
                        other => {
                            return Err(Error::Distributed(format!(
                                "bad handshake reply: {other:?}"
                            )))
                        }
                    }
                    let rows = shard_data.rows();
                    Message::train(shard_data, &params, &sampling, seed)
                        .write_to(&mut stream)?;
                    match Message::read_from(&mut stream)? {
                        Message::TrainDone { sv, iterations, converged, .. } => {
                            let report = WorkerReport {
                                worker: i,
                                shard_rows: rows,
                                sv_count: sv.rows(),
                                iterations: iterations as usize,
                                converged,
                            };
                            Message::Shutdown.write_to(&mut stream).ok();
                            Ok((sv, report))
                        }
                        Message::TrainFailed { reason } => {
                            Err(Error::Distributed(format!("worker {i}: {reason}")))
                        }
                        other => Err(Error::Distributed(format!("unexpected {other:?}"))),
                    }
                })
            })
            .collect();
        handles.into_iter().map(|h| h.join().expect("controller thread panicked")).collect()
    });

    let mut sv_sets = Vec::new();
    let mut reports = Vec::new();
    for r in results {
        let (sv, report) = r?;
        sv_sets.push(sv);
        reports.push(report);
    }
    let (model, union_rows, solver) = combine_detailed(sv_sets, params)?;
    Ok(DistributedOutcome { model, reports, union_rows, solver })
}

/// Cluster-wide metrics pulled by [`cluster_stats`].
#[derive(Clone, Debug)]
pub struct ClusterStats {
    /// Each worker's exact counter snapshot, in `addrs` order.
    pub per_worker: Vec<(std::net::SocketAddr, Vec<(String, u64)>)>,
    /// [`crate::metrics::aggregate`] of every snapshot: per-key sums
    /// across the fleet.
    pub totals: Vec<(String, u64)>,
}

/// Pull every worker's metrics over the v2 [`Message::StatsRequest`]
/// frame and aggregate the exact counters cluster-wide. Fails if any
/// worker is unreachable or negotiates below v2 (stats frames must
/// never be sent on a v1 session).
pub fn cluster_stats(addrs: &[std::net::SocketAddr]) -> Result<ClusterStats> {
    if addrs.is_empty() {
        return Err(Error::Distributed("no worker addresses".into()));
    }
    let mut per_worker = Vec::with_capacity(addrs.len());
    for &addr in addrs {
        let mut stream = TcpStream::connect(addr)?;
        Message::Hello { version: PROTOCOL_VERSION }.write_to(&mut stream)?;
        let v = match Message::read_from(&mut stream)? {
            Message::HelloAck { version } => negotiate(version).ok_or_else(|| {
                Error::Distributed(format!("worker {addr}: bad version {version}"))
            })?,
            other => {
                return Err(Error::Distributed(format!(
                    "worker {addr}: bad handshake reply: {other:?}"
                )))
            }
        };
        if v < 2 {
            return Err(Error::Distributed(format!(
                "worker {addr} negotiated v{v}; stats need v2"
            )));
        }
        Message::StatsRequest.write_to(&mut stream)?;
        match Message::read_from(&mut stream)? {
            Message::StatsReply { counters, .. } => per_worker.push((addr, counters)),
            other => {
                return Err(Error::Distributed(format!(
                    "worker {addr}: unexpected {other:?}"
                )))
            }
        }
        Message::Shutdown.write_to(&mut stream).ok();
    }
    let snapshots: Vec<Vec<(String, u64)>> =
        per_worker.iter().map(|(_, c)| c.clone()).collect();
    let totals = crate::metrics::aggregate(&snapshots);
    Ok(ClusterStats { per_worker, totals })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{donut::TwoDonut, Generator};

    #[test]
    fn tcp_cluster_end_to_end() {
        let mut w1 = WorkerServer::spawn("127.0.0.1:0").unwrap();
        let mut w2 = WorkerServer::spawn("127.0.0.1:0").unwrap();
        let addrs = vec![w1.addr(), w2.addr()];

        let data = TwoDonut::default().generate(4000, 8);
        let params = SvddParams::gaussian(0.4, 0.001);
        let cfg = DistributedConfig {
            workers: 4, // 4 shards over 2 workers (round robin)
            sampling: SamplingConfig { sample_size: 11, ..Default::default() },
            seed: 5,
            shuffle_seed: None,
        };
        let out = train_tcp_cluster(&data, &params, &cfg, &addrs).unwrap();
        assert_eq!(out.reports.len(), 4);
        assert!(out.model.r2() > 0.5);
        w1.stop();
        w2.stop();
    }

    #[test]
    fn tcp_matches_local_cluster() {
        let mut w = WorkerServer::spawn("127.0.0.1:0").unwrap();
        let data = TwoDonut::default().generate(2000, 9);
        let params = SvddParams::gaussian(0.4, 0.001);
        let cfg = DistributedConfig {
            workers: 2,
            sampling: SamplingConfig { sample_size: 8, ..Default::default() },
            seed: 21,
            shuffle_seed: None,
        };
        let tcp = train_tcp_cluster(&data, &params, &cfg, &[w.addr()]).unwrap();
        let local = super::super::local::train_local_cluster(&data, &params, &cfg).unwrap();
        // same shards, same seeds, same algorithm -> identical result
        assert_eq!(tcp.union_rows, local.union_rows);
        assert!((tcp.model.r2() - local.model.r2()).abs() < 1e-12);
        w.stop();
    }

    #[test]
    fn no_addresses_rejected() {
        let data = TwoDonut::default().generate(100, 1);
        let params = SvddParams::gaussian(0.4, 0.01);
        let cfg = DistributedConfig::default();
        assert!(train_tcp_cluster(&data, &params, &cfg, &[]).is_err());
        assert!(cluster_stats(&[]).is_err());
    }

    #[test]
    fn cluster_stats_aggregates_worker_counters() {
        let mut w1 = WorkerServer::spawn("127.0.0.1:0").unwrap();
        let mut w2 = WorkerServer::spawn("127.0.0.1:0").unwrap();
        let addrs = vec![w1.addr(), w2.addr()];
        let data = TwoDonut::default().generate(3000, 3);
        let params = SvddParams::gaussian(0.4, 0.001);
        let cfg = DistributedConfig {
            workers: 2,
            sampling: SamplingConfig { sample_size: 9, ..Default::default() },
            seed: 11,
            shuffle_seed: None,
        };
        let out = train_tcp_cluster(&data, &params, &cfg, &addrs).unwrap();
        let stats = cluster_stats(&addrs).unwrap();
        assert_eq!(stats.per_worker.len(), 2);
        let total = |key: &str| {
            stats
                .totals
                .iter()
                .find(|(k, _)| k == key)
                .unwrap_or_else(|| panic!("missing key {key}"))
                .1
        };
        // every worker trained once; the aggregated totals must match
        // the per-worker reports exactly (counters, not averaged rates)
        let iters: u64 = out.reports.iter().map(|r| r.iterations as u64).sum();
        assert_eq!(total("train_iterations"), iters);
        assert_eq!(total("solver_calls"), stats
            .per_worker
            .iter()
            .map(|(_, c)| c.iter().find(|(k, _)| k == "solver_calls").unwrap().1)
            .sum::<u64>());
        assert!(total("smo_iterations") > 0);
        w1.stop();
        w2.stop();
    }
}
