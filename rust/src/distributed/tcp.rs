//! TCP transport: a worker daemon (`fastsvdd worker --listen ...`) and
//! a fault-tolerant controller client, speaking the [`super::message`]
//! protocol over length-prefixed frames. One thread per accepted
//! connection; the handshake pins the protocol version.
//!
//! Controller fault tolerance:
//! - every socket carries `connect`/`read`/`write` deadlines
//!   ([`DistributedConfig::worker_timeout`]), so a hung peer can never
//!   block the run;
//! - when a training reply is late, liveness is probed with a
//!   [`Message::Heartbeat`] on a fresh connection — "still solving" and
//!   "dead" are different facts, and only the latter fails the attempt;
//! - each worker address runs through a [`WorkerState`] machine
//!   (healthy → suspect → dead); a dead worker's controller thread
//!   exits and its shards are reassigned to survivors;
//! - failed shards re-enter a shared work queue with exponential
//!   backoff + deterministic jitter ([`RetrySchedule`]), bounded by
//!   [`DistributedConfig::max_retries`] attempts beyond the first;
//! - when fewer than [`DistributedConfig::min_workers`] workers remain
//!   alive (but at least one), remaining shards are trained locally in
//!   the controller; zero live workers fails the run with
//!   [`Error::Distributed`].
//!
//! Results are keyed by shard index and combined in shard order, so the
//! final model is independent of which worker trained which shard and
//! of retry timing — a clean run and a run that survived failures
//! produce bit-identical models.
//!
//! Each worker keeps a [`Metrics`] registry of its solver telemetry; a
//! v2 peer pulls it with [`Message::StatsRequest`], and
//! [`cluster_stats`] fans that request across a worker fleet and
//! [`crate::metrics::aggregate`]s the exact counters cluster-wide.
//! Worker misbehaviour for chaos testing is injected with a
//! deterministic [`FaultPlan`] (see [`super::faults`]).

use std::collections::BTreeMap;
use std::io::{ErrorKind, Write};
use std::net::{SocketAddr, TcpListener, TcpStream, ToSocketAddrs};
use std::path::Path;
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::data::csv::CsvChunks;
use crate::error::{Error, Result};
use crate::metrics::Metrics;
use crate::obs;
use crate::sampling::{SamplingConfig, SamplingTrainer};
use crate::svdd::trainer::SvddParams;
use crate::svdd::Kernel;
use crate::util::matrix::Matrix;
use crate::util::rng::Xoshiro256;
use rand_core::RngCore;

use super::controller::{
    combine_with_mode, shard_with_shuffle, DistributedConfig, DistributedOutcome, RetryStats,
    WorkerReport,
};
use super::faults::{FaultInjector, FaultPlan, ReplyFault};
use super::message::{negotiate, Message, PROTOCOL_VERSION};

/// Deadline for [`cluster_stats`] sockets (the config-driven paths use
/// [`DistributedConfig::worker_timeout`] instead).
pub const DEFAULT_CLUSTER_TIMEOUT: Duration = Duration::from_secs(30);

/// How many times a quiet-but-heartbeating worker is granted another
/// `worker_timeout` of waiting before the attempt is failed anyway. The
/// cap keeps a live-but-stuck worker from blocking the run forever
/// (worst case one attempt waits `(MAX_GRACE_PROBES + 1) ×
/// worker_timeout`).
const MAX_GRACE_PROBES: u32 = 64;

/// A running worker server (owns its listener thread).
pub struct WorkerServer {
    addr: SocketAddr,
    stop: Arc<AtomicBool>,
    handle: Option<std::thread::JoinHandle<()>>,
    metrics: Arc<Metrics>,
}

impl WorkerServer {
    /// Bind `addr` (e.g. "127.0.0.1:0") and serve train requests until
    /// [`WorkerServer::stop`] or process exit.
    pub fn spawn(addr: impl ToSocketAddrs) -> Result<WorkerServer> {
        WorkerServer::spawn_with_faults(addr, None)
    }

    /// [`WorkerServer::spawn`] with a deterministic misbehaviour
    /// schedule (chaos testing; see [`super::faults`]). `None` serves
    /// faithfully.
    pub fn spawn_with_faults(
        addr: impl ToSocketAddrs,
        plan: Option<FaultPlan>,
    ) -> Result<WorkerServer> {
        let listener = TcpListener::bind(addr)?;
        let local = listener.local_addr()?;
        listener.set_nonblocking(true)?;
        let stop = Arc::new(AtomicBool::new(false));
        let stop2 = stop.clone();
        let metrics = Arc::new(Metrics::new());
        let accept_metrics = metrics.clone();
        let injector = Arc::new(match plan {
            Some(p) => FaultInjector::new(p),
            None => FaultInjector::none(),
        });
        let handle = std::thread::spawn(move || {
            while !stop2.load(Ordering::Relaxed) {
                match listener.accept() {
                    Ok((stream, _)) => {
                        stream.set_nonblocking(false).ok();
                        let stop3 = stop2.clone();
                        let mx = accept_metrics.clone();
                        let inj = injector.clone();
                        std::thread::spawn(move || {
                            let _ = handle_connection(stream, &stop3, &mx, &inj);
                        });
                    }
                    Err(e) if e.kind() == ErrorKind::WouldBlock => {
                        std::thread::sleep(Duration::from_millis(10));
                    }
                    Err(_) => break,
                }
            }
        });
        Ok(WorkerServer { addr: local, stop, handle: Some(handle), metrics })
    }

    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// The worker's metrics registry (shard-train telemetry).
    pub fn metrics(&self) -> &Arc<Metrics> {
        &self.metrics
    }

    /// Ask the accept loop to exit (in-flight connections finish).
    pub fn stop(&mut self) {
        self.stop.store(true, Ordering::Relaxed);
        if let Some(h) = self.handle.take() {
            h.join().ok();
        }
    }
}

impl Drop for WorkerServer {
    fn drop(&mut self) {
        self.stop();
    }
}

fn handle_connection(
    mut stream: TcpStream,
    stop: &AtomicBool,
    metrics: &Metrics,
    faults: &FaultInjector,
) -> Result<()> {
    // a fault-killed worker plays dead: drop without a byte
    if faults.killed() {
        return Err(Error::Distributed("fault injection: worker is dead".into()));
    }
    // handshake
    let session_version = match Message::read_from(&mut stream)? {
        Message::Hello { version } => match negotiate(version) {
            Some(v) => {
                Message::HelloAck { version: v }.write_to(&mut stream)?;
                v
            }
            None => {
                Message::TrainFailed {
                    reason: format!("peer version {version} too old (< min supported)"),
                }
                .write_to(&mut stream)?;
                return Err(Error::Distributed("handshake version mismatch".into()));
            }
        },
        other => {
            return Err(Error::Distributed(format!("expected Hello, got {other:?}")));
        }
    };
    // serve
    while !stop.load(Ordering::Relaxed) {
        let msg = match Message::read_from(&mut stream) {
            Ok(m) => m,
            Err(_) => break, // peer went away
        };
        if faults.killed() {
            return Err(Error::Distributed("fault injection: worker is dead".into()));
        }
        // never accept a frame the negotiated session version cannot carry
        if msg.min_version() > session_version {
            return Err(Error::Distributed(format!(
                "v{} frame on a v{session_version} session: {msg:?}",
                msg.min_version()
            )));
        }
        match msg {
            Message::Train { shard, bw, outlier_fraction, sample_size, max_iter, seed } => {
                let params = SvddParams {
                    kernel: Kernel::gaussian(bw),
                    outlier_fraction,
                    ..Default::default()
                };
                let cfg = SamplingConfig {
                    sample_size: sample_size as usize,
                    max_iter: max_iter as usize,
                    ..Default::default()
                };
                let reply = match SamplingTrainer::new(params, cfg).train(&shard, seed) {
                    Ok(out) => {
                        metrics.record_training(out.solver_calls, out.iterations, &out.solver);
                        Message::TrainDone {
                            sv: out.model.support_vectors().clone(),
                            r2: out.model.r2(),
                            iterations: out.iterations as u32,
                            converged: out.converged,
                        }
                    }
                    Err(e) => Message::TrainFailed { reason: e.to_string() },
                };
                match faults.on_train_reply() {
                    ReplyFault::Drop => {
                        return Err(Error::Distributed("fault injection: dropped reply".into()));
                    }
                    ReplyFault::Corrupt { delay } => {
                        if !delay.is_zero() {
                            std::thread::sleep(delay);
                        }
                        write_corrupted(&reply, &mut stream)?;
                    }
                    ReplyFault::Send { delay } => {
                        if !delay.is_zero() {
                            std::thread::sleep(delay);
                        }
                        reply.write_to(&mut stream)?;
                    }
                }
            }
            Message::Heartbeat => {
                metrics.heartbeats_served.inc();
                Message::HeartbeatAck.write_to(&mut stream)?;
            }
            Message::StatsRequest => {
                Message::StatsReply {
                    text: metrics.render_prometheus(),
                    counters: metrics.snapshot(),
                }
                .write_to(&mut stream)?;
            }
            Message::Shutdown => break,
            other => {
                return Err(Error::Distributed(format!("unexpected {other:?}")));
            }
        }
    }
    Ok(())
}

/// Write `msg` as a correctly-framed but garbage-bodied message (every
/// body byte XORed), so the peer's decode fails without desyncing the
/// length-prefixed stream — the fault-injection shape of "a worker sent
/// us garbage".
fn write_corrupted(msg: &Message, w: &mut impl Write) -> Result<()> {
    let mut body = msg.encode();
    for b in &mut body {
        *b ^= 0xA5;
    }
    w.write_all(&(body.len() as u32).to_le_bytes())?;
    w.write_all(&body)?;
    w.flush()?;
    Ok(())
}

// ------------------------------------------------- controller: health

/// Controller-side liveness verdict for one worker address.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum WorkerState {
    /// Serving normally.
    Healthy,
    /// One failed attempt, but the worker still acks heartbeats — the
    /// failure may have been shard- or connection-specific.
    Suspect,
    /// Two consecutive failures, or any failure with no heartbeat ack.
    /// Dead workers get no more work; their shards are reassigned.
    Dead,
}

/// The healthy → suspect → dead state machine. Any successful attempt
/// resets to healthy; a failure whose liveness probe goes unanswered is
/// immediately dead (the worker is gone, not struggling).
#[derive(Clone, Copy, Debug, Default)]
pub struct WorkerHealth {
    state: Option<WorkerState>,
}

impl WorkerHealth {
    pub fn state(&self) -> WorkerState {
        self.state.unwrap_or(WorkerState::Healthy)
    }

    pub fn on_success(&mut self) {
        self.state = Some(WorkerState::Healthy);
    }

    /// Record a failed attempt; `probe_acked` says whether the worker
    /// answered a heartbeat afterwards.
    pub fn on_failure(&mut self, probe_acked: bool) {
        self.state = Some(match (self.state(), probe_acked) {
            (_, false) => WorkerState::Dead,
            (WorkerState::Healthy, true) => WorkerState::Suspect,
            (WorkerState::Suspect | WorkerState::Dead, true) => WorkerState::Dead,
        });
    }
}

// ------------------------------------------------ controller: backoff

/// Exponential backoff with deterministic jitter for shard retries:
/// `base · 2^attempt + jitter`, capped at `cap`. The jitter is drawn
/// from a [`Xoshiro256`] stream keyed on (run seed, shard index,
/// attempt) — no wall clock, no global RNG — so a given run retries on
/// an exactly reproducible schedule.
#[derive(Clone, Copy, Debug)]
pub struct RetrySchedule {
    pub base: Duration,
    pub cap: Duration,
}

impl RetrySchedule {
    /// Derive from the per-attempt socket deadline: backoff starts at
    /// an eighth of it (at least 10ms) and never exceeds it.
    pub fn from_timeout(worker_timeout: Duration) -> RetrySchedule {
        let base = (worker_timeout / 8).max(Duration::from_millis(10));
        RetrySchedule { base, cap: worker_timeout.max(base) }
    }

    /// Delay before retrying a shard whose 0-based `attempt` just
    /// failed. Jitter is uniform in `[0, base/2)`.
    pub fn delay(&self, attempt: usize, seed: u64, shard: u64) -> Duration {
        let exp = self.base.saturating_mul(1u32 << attempt.min(16) as u32);
        let half_us = (self.base / 2).as_micros() as u64;
        let jitter_us = if half_us == 0 {
            0
        } else {
            let mut rng = Xoshiro256::new(seed ^ 0x9E37_79B9_7F4A_7C15).stream(shard);
            let mut j = 0;
            for _ in 0..=attempt {
                j = rng.next_u64();
            }
            j % half_us
        };
        (exp + Duration::from_micros(jitter_us)).min(self.cap)
    }
}

// -------------------------------------------- controller: work queue

/// Where shards come from: pre-sharded in memory, or streamed out of a
/// CSV in bounded chunks (each chunk is one shard) so the controller
/// never materialises the full dataset.
enum ShardSource {
    Memory(std::vec::IntoIter<Matrix>),
    Csv(Box<CsvChunks>),
}

impl ShardSource {
    fn next_shard(&mut self) -> Result<Option<Matrix>> {
        match self {
            ShardSource::Memory(it) => Ok(it.next()),
            ShardSource::Csv(chunks) => chunks.next_chunk(),
        }
    }
}

struct Task {
    shard: usize,
    seed: u64,
    data: Matrix,
    /// 0-based attempts already consumed before this one.
    attempt: usize,
    not_before: Instant,
    last_worker: Option<usize>,
}

struct CtrlState {
    source: ShardSource,
    next_shard: usize,
    source_done: bool,
    retry: Vec<Task>,
    done: BTreeMap<usize, (Matrix, WorkerReport)>,
    in_flight: usize,
    alive: usize,
    fatal: Option<String>,
    stats: RetryStats,
}

struct Shared {
    state: Mutex<CtrlState>,
    cv: Condvar,
    params: SvddParams,
    sampling: SamplingConfig,
    seed: u64,
    timeout: Duration,
    max_retries: usize,
    min_workers: usize,
    backoff: RetrySchedule,
}

/// Pull the next task for controller thread `w`: an eligible retry
/// first (counting cross-worker reassignment), else a fresh shard from
/// the source. Returns the task plus whether the run has degraded below
/// `min_workers` (train locally). `None` means this thread is done —
/// every shard has a result, or the run failed.
fn acquire(shared: &Shared, w: usize) -> Option<(Task, bool)> {
    let mut st = shared.state.lock().unwrap();
    loop {
        if st.fatal.is_some() {
            return None;
        }
        let now = Instant::now();
        if let Some(pos) = st.retry.iter().position(|t| t.not_before <= now) {
            let task = st.retry.swap_remove(pos);
            if task.last_worker.is_some_and(|lw| lw != w) {
                st.stats.shards_reassigned += 1;
            }
            st.in_flight += 1;
            let degraded = st.alive < shared.min_workers;
            return Some((task, degraded));
        }
        if !st.source_done {
            match st.source.next_shard() {
                Ok(Some(data)) => {
                    let shard = st.next_shard;
                    st.next_shard += 1;
                    st.in_flight += 1;
                    let seed = Xoshiro256::new(shared.seed).stream(shard as u64).next_u64();
                    let degraded = st.alive < shared.min_workers;
                    let task = Task {
                        shard,
                        seed,
                        data,
                        attempt: 0,
                        not_before: now,
                        last_worker: None,
                    };
                    return Some((task, degraded));
                }
                Ok(None) => {
                    st.source_done = true;
                    continue;
                }
                Err(e) => {
                    st.fatal = Some(format!("shard source: {e}"));
                    shared.cv.notify_all();
                    return None;
                }
            }
        }
        if st.retry.is_empty() && st.in_flight == 0 {
            return None; // drained: every shard has a result
        }
        // a retry may become eligible or an in-flight attempt may
        // requeue work; short timed waits keep this race-free without
        // tracking exact wake deadlines
        let (guard, _) = shared.cv.wait_timeout(st, Duration::from_millis(25)).unwrap();
        st = guard;
    }
}

/// One controller thread per worker address: pull tasks, execute
/// remotely (or locally once degraded), feed the state machine, requeue
/// failures with backoff. Exits when its worker is declared dead or the
/// queue is drained.
fn worker_loop(shared: &Shared, w: usize, addr: SocketAddr) {
    let mut health = WorkerHealth::default();
    while let Some((task, degraded)) = acquire(shared, w) {
        let mut span = obs::Span::enter("distributed.shard");
        // panic-capture: one poisoned attempt surfaces as a failed
        // attempt (retried like any other), never an aborted process
        let attempt_res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            if degraded {
                train_shard_inprocess(&task, shared)
            } else {
                run_shard_remote(addr, &task, shared)
            }
        }))
        .unwrap_or_else(|p| {
            Err(Error::Distributed(format!(
                "shard {} controller thread panicked: {}",
                task.shard,
                panic_message(p.as_ref())
            )))
        });
        if span.is_live() {
            span.u64("shard", task.shard as u64);
            span.u64("attempt", task.attempt as u64 + 1);
            span.u64("worker", w as u64);
            span.u64("local", u64::from(degraded));
            span.u64("ok", u64::from(attempt_res.is_ok()));
        }
        drop(span);
        match attempt_res {
            Ok((sv, iterations, converged)) => {
                let mut st = shared.state.lock().unwrap();
                st.in_flight -= 1;
                if degraded {
                    st.stats.shards_local_fallback += 1;
                } else {
                    health.on_success();
                }
                let report = WorkerReport {
                    worker: task.shard,
                    shard_rows: task.data.rows(),
                    sv_count: sv.rows(),
                    iterations: iterations as usize,
                    converged,
                };
                st.done.insert(task.shard, (sv, report));
                shared.cv.notify_all();
            }
            Err(e) => {
                // probe liveness on a fresh connection: "this shard
                // attempt failed" and "the worker is gone" are
                // different facts with different consequences
                let probe_acked = !degraded && heartbeat_probe(addr, shared.timeout);
                if !degraded {
                    health.on_failure(probe_acked);
                }
                let mut st = shared.state.lock().unwrap();
                st.in_flight -= 1;
                st.stats.worker_failures += 1;
                if degraded {
                    // local execution failing is a training error, not
                    // a transport fault — retrying cannot help
                    st.fatal = Some(format!("local fallback for shard {}: {e}", task.shard));
                } else if task.attempt >= shared.max_retries {
                    st.fatal = Some(format!(
                        "shard {} failed after {} attempts (last worker {addr}): {e}",
                        task.shard,
                        task.attempt + 1
                    ));
                } else {
                    let delay = shared.backoff.delay(task.attempt, shared.seed, task.shard as u64);
                    obs::emit(
                        "distributed.retry",
                        vec![
                            ("shard", obs::Value::U64(task.shard as u64)),
                            ("attempt", obs::Value::U64(task.attempt as u64 + 1)),
                            ("delay_us", obs::Value::U64(delay.as_micros() as u64)),
                        ],
                    );
                    st.stats.shard_retries += 1;
                    st.retry.push(Task {
                        attempt: task.attempt + 1,
                        not_before: Instant::now() + delay,
                        last_worker: Some(w),
                        ..task
                    });
                }
                if !degraded && health.state() == WorkerState::Dead {
                    st.stats.workers_lost += 1;
                    st.alive -= 1;
                    obs::emit(
                        "distributed.worker_dead",
                        vec![("worker", obs::Value::U64(w as u64))],
                    );
                    shared.cv.notify_all();
                    return;
                }
                shared.cv.notify_all();
            }
        }
    }
}

/// One remote training attempt over a fresh deadline-guarded
/// connection. While the reply is late but the worker still acks
/// heartbeats, the wait is extended (a long solve is not a failure) up
/// to [`MAX_GRACE_PROBES`] times.
fn run_shard_remote(
    addr: SocketAddr,
    task: &Task,
    shared: &Shared,
) -> Result<(Matrix, u32, bool)> {
    let mut stream = connect(addr, shared.timeout)?;
    handshake(&mut stream, addr)?;
    Message::train(task.data.clone(), &shared.params, &shared.sampling, task.seed)
        .write_to(&mut stream)?;
    // wait via peek so a timeout never consumes partial frame bytes
    let mut probes = 0u32;
    loop {
        let mut first = [0u8; 1];
        match stream.peek(&mut first) {
            Ok(0) => {
                return Err(Error::Distributed(format!("worker {addr}: connection closed")));
            }
            Ok(_) => break,
            Err(e) if is_timeout(&e) => {
                probes += 1;
                if probes > MAX_GRACE_PROBES || !heartbeat_probe(addr, shared.timeout) {
                    return Err(Error::Distributed(format!(
                        "worker {addr}: no reply within {:?} and no heartbeat",
                        shared.timeout
                    )));
                }
            }
            Err(e) => return Err(e.into()),
        }
    }
    match Message::read_from(&mut stream)? {
        Message::TrainDone { sv, iterations, converged, .. } => {
            Message::Shutdown.write_to(&mut stream).ok();
            Ok((sv, iterations, converged))
        }
        Message::TrainFailed { reason } => {
            Err(Error::Distributed(format!("worker {addr}: {reason}")))
        }
        other => Err(Error::Distributed(format!("worker {addr}: unexpected {other:?}"))),
    }
}

/// Degraded-mode execution: the same computation a worker would run,
/// in-process — bit-identical to the remote result for the same
/// (shard, seed).
fn train_shard_inprocess(task: &Task, shared: &Shared) -> Result<(Matrix, u32, bool)> {
    let out = SamplingTrainer::new(shared.params, shared.sampling).train(&task.data, task.seed)?;
    Ok((out.model.support_vectors().clone(), out.iterations as u32, out.converged))
}

/// Is the worker alive? Fresh short-deadline connection, handshake,
/// `Heartbeat` → `HeartbeatAck`. A pre-v4 worker that answers the
/// handshake counts as alive (it cannot ack but it is clearly serving).
fn heartbeat_probe(addr: SocketAddr, timeout: Duration) -> bool {
    let attempt = || -> Result<bool> {
        let mut stream = connect(addr, timeout)?;
        let v = handshake(&mut stream, addr)?;
        if v < 4 {
            return Ok(true);
        }
        Message::Heartbeat.write_to(&mut stream)?;
        Ok(matches!(Message::read_from(&mut stream)?, Message::HeartbeatAck))
    };
    attempt().unwrap_or(false)
}

fn connect(addr: SocketAddr, timeout: Duration) -> Result<TcpStream> {
    let stream = TcpStream::connect_timeout(&addr, timeout)?;
    stream.set_read_timeout(Some(timeout))?;
    stream.set_write_timeout(Some(timeout))?;
    Ok(stream)
}

fn handshake(stream: &mut TcpStream, addr: SocketAddr) -> Result<u32> {
    Message::Hello { version: PROTOCOL_VERSION }.write_to(stream)?;
    match Message::read_from(stream)? {
        Message::HelloAck { version } => negotiate(version)
            .ok_or_else(|| Error::Distributed(format!("worker {addr}: bad version {version}"))),
        other => Err(Error::Distributed(format!("worker {addr}: bad handshake reply: {other:?}"))),
    }
}

fn is_timeout(e: &std::io::Error) -> bool {
    matches!(e.kind(), ErrorKind::WouldBlock | ErrorKind::TimedOut)
}

fn panic_message(p: &(dyn std::any::Any + Send)) -> String {
    if let Some(s) = p.downcast_ref::<&str>() {
        (*s).to_string()
    } else if let Some(s) = p.downcast_ref::<String>() {
        s.clone()
    } else {
        "unknown panic payload".to_string()
    }
}

// --------------------------------------------- controller: entry points

/// Controller over TCP workers: shard the data, fan the shards over the
/// worker addresses through the fault-tolerant work queue, gather SV
/// sets, combine (per [`DistributedConfig::combine`]).
pub fn train_tcp_cluster(
    data: &Matrix,
    params: &SvddParams,
    cfg: &DistributedConfig,
    addrs: &[SocketAddr],
) -> Result<DistributedOutcome> {
    let shards = shard_with_shuffle(data, cfg.workers, cfg.shuffle_seed);
    run_cluster(ShardSource::Memory(shards.into_iter()), params, cfg, addrs)
}

/// [`train_tcp_cluster`] over a CSV streamed in bounded chunks of
/// `chunk_rows` rows — each chunk becomes one shard, shipped to a
/// worker as soon as a controller thread is free, so the controller
/// holds at most (live workers + retry queue) chunks in memory instead
/// of the whole dataset. `cfg.workers` is ignored (the shard count is
/// the chunk count) and `cfg.shuffle_seed` is rejected: a pre-shuffle
/// needs the full dataset, which streaming exists to avoid.
pub fn train_tcp_cluster_stream(
    path: &Path,
    has_header: bool,
    chunk_rows: usize,
    params: &SvddParams,
    cfg: &DistributedConfig,
    addrs: &[SocketAddr],
) -> Result<DistributedOutcome> {
    if cfg.shuffle_seed.is_some() {
        return Err(Error::Config(
            "shuffle_seed needs the in-memory path; streamed shards are chunk-ordered".into(),
        ));
    }
    let chunks = CsvChunks::open(path, has_header, chunk_rows)?;
    run_cluster(ShardSource::Csv(Box::new(chunks)), params, cfg, addrs)
}

fn run_cluster(
    source: ShardSource,
    params: &SvddParams,
    cfg: &DistributedConfig,
    addrs: &[SocketAddr],
) -> Result<DistributedOutcome> {
    if addrs.is_empty() {
        return Err(Error::Distributed("no worker addresses".into()));
    }
    let shared = Shared {
        state: Mutex::new(CtrlState {
            source,
            next_shard: 0,
            source_done: false,
            retry: Vec::new(),
            done: BTreeMap::new(),
            in_flight: 0,
            alive: addrs.len(),
            fatal: None,
            stats: RetryStats::default(),
        }),
        cv: Condvar::new(),
        params: *params,
        sampling: cfg.sampling,
        seed: cfg.seed,
        timeout: cfg.worker_timeout,
        max_retries: cfg.max_retries,
        min_workers: cfg.min_workers,
        backoff: RetrySchedule::from_timeout(cfg.worker_timeout),
    };
    let panics: Vec<String> = std::thread::scope(|scope| {
        let handles: Vec<_> = addrs
            .iter()
            .enumerate()
            .map(|(w, &addr)| {
                let shared = &shared;
                scope.spawn(move || worker_loop(shared, w, addr))
            })
            .collect();
        handles
            .into_iter()
            .filter_map(|h| h.join().err().map(|p| panic_message(p.as_ref())))
            .collect()
    });
    let mut st = shared
        .state
        .into_inner()
        .map_err(|_| Error::Distributed("controller state poisoned by a panicked thread".into()))?;
    if let Some(p) = panics.first() {
        return Err(Error::Distributed(format!("controller thread panicked: {p}")));
    }
    if let Some(f) = st.fatal.take() {
        return Err(Error::Distributed(f));
    }
    if !st.retry.is_empty() || !st.source_done {
        return Err(Error::Distributed(format!(
            "all {} worker(s) dead; {} queued shard(s) unfinished",
            addrs.len(),
            st.retry.len().max(1)
        )));
    }
    let mut sv_sets = Vec::with_capacity(st.done.len());
    let mut reports = Vec::with_capacity(st.done.len());
    for (_, (sv, report)) in st.done {
        sv_sets.push(sv);
        reports.push(report);
    }
    let (model, union_rows, solver, combine_solves) =
        combine_with_mode(sv_sets, params, cfg.combine)?;
    Ok(DistributedOutcome {
        model,
        reports,
        union_rows,
        solver,
        combine_solves,
        retry: st.stats,
    })
}

/// Cluster-wide metrics pulled by [`cluster_stats`].
#[derive(Clone, Debug)]
pub struct ClusterStats {
    /// Each worker's exact counter snapshot, in `addrs` order.
    pub per_worker: Vec<(SocketAddr, Vec<(String, u64)>)>,
    /// [`crate::metrics::aggregate`] of every snapshot: per-key sums
    /// across the fleet.
    pub totals: Vec<(String, u64)>,
}

/// Pull every worker's metrics over the v2 [`Message::StatsRequest`]
/// frame and aggregate the exact counters cluster-wide, with
/// [`DEFAULT_CLUSTER_TIMEOUT`] deadlines on every socket. Fails if any
/// worker is unreachable or negotiates below v2 (stats frames must
/// never be sent on a v1 session).
pub fn cluster_stats(addrs: &[SocketAddr]) -> Result<ClusterStats> {
    cluster_stats_with_timeout(addrs, DEFAULT_CLUSTER_TIMEOUT)
}

/// [`cluster_stats`] with an explicit per-socket deadline (wire it to
/// the run's `worker_timeout` when scraping a training cluster).
pub fn cluster_stats_with_timeout(
    addrs: &[SocketAddr],
    timeout: Duration,
) -> Result<ClusterStats> {
    if addrs.is_empty() {
        return Err(Error::Distributed("no worker addresses".into()));
    }
    let mut per_worker = Vec::with_capacity(addrs.len());
    for &addr in addrs {
        let mut stream = connect(addr, timeout)?;
        let v = handshake(&mut stream, addr)?;
        if v < 2 {
            return Err(Error::Distributed(format!(
                "worker {addr} negotiated v{v}; stats need v2"
            )));
        }
        Message::StatsRequest.write_to(&mut stream)?;
        match Message::read_from(&mut stream)? {
            Message::StatsReply { counters, .. } => per_worker.push((addr, counters)),
            other => {
                return Err(Error::Distributed(format!("worker {addr}: unexpected {other:?}")))
            }
        }
        Message::Shutdown.write_to(&mut stream).ok();
    }
    let snapshots: Vec<Vec<(String, u64)>> = per_worker.iter().map(|(_, c)| c.clone()).collect();
    let totals = crate::metrics::aggregate(&snapshots);
    Ok(ClusterStats { per_worker, totals })
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{donut::TwoDonut, Generator};

    #[test]
    fn tcp_cluster_end_to_end() {
        let mut w1 = WorkerServer::spawn("127.0.0.1:0").unwrap();
        let mut w2 = WorkerServer::spawn("127.0.0.1:0").unwrap();
        let addrs = vec![w1.addr(), w2.addr()];

        let data = TwoDonut::default().generate(4000, 8);
        let params = SvddParams::gaussian(0.4, 0.001);
        let cfg = DistributedConfig {
            workers: 4, // 4 shards over 2 workers
            sampling: SamplingConfig { sample_size: 11, ..Default::default() },
            seed: 5,
            ..Default::default()
        };
        let out = train_tcp_cluster(&data, &params, &cfg, &addrs).unwrap();
        assert_eq!(out.reports.len(), 4);
        assert!(out.model.r2() > 0.5);
        // clean run: no failures, no retries, one flat combine solve
        assert_eq!(out.retry, RetryStats::default());
        assert_eq!(out.combine_solves, 1);
        w1.stop();
        w2.stop();
    }

    #[test]
    fn tcp_matches_local_cluster() {
        let mut w = WorkerServer::spawn("127.0.0.1:0").unwrap();
        let data = TwoDonut::default().generate(2000, 9);
        let params = SvddParams::gaussian(0.4, 0.001);
        let cfg = DistributedConfig {
            workers: 2,
            sampling: SamplingConfig { sample_size: 8, ..Default::default() },
            seed: 21,
            ..Default::default()
        };
        let tcp = train_tcp_cluster(&data, &params, &cfg, &[w.addr()]).unwrap();
        let local = super::super::local::train_local_cluster(&data, &params, &cfg).unwrap();
        // same shards, same seeds, same algorithm -> identical result
        assert_eq!(tcp.union_rows, local.union_rows);
        assert!((tcp.model.r2() - local.model.r2()).abs() < 1e-12);
        w.stop();
    }

    #[test]
    fn no_addresses_rejected() {
        let data = TwoDonut::default().generate(100, 1);
        let params = SvddParams::gaussian(0.4, 0.01);
        let cfg = DistributedConfig::default();
        assert!(train_tcp_cluster(&data, &params, &cfg, &[]).is_err());
        assert!(cluster_stats(&[]).is_err());
    }

    #[test]
    fn cluster_stats_aggregates_worker_counters() {
        let mut w1 = WorkerServer::spawn("127.0.0.1:0").unwrap();
        let mut w2 = WorkerServer::spawn("127.0.0.1:0").unwrap();
        let addrs = vec![w1.addr(), w2.addr()];
        let data = TwoDonut::default().generate(3000, 3);
        let params = SvddParams::gaussian(0.4, 0.001);
        let cfg = DistributedConfig {
            workers: 2,
            sampling: SamplingConfig { sample_size: 9, ..Default::default() },
            seed: 11,
            ..Default::default()
        };
        let out = train_tcp_cluster(&data, &params, &cfg, &addrs).unwrap();
        let stats = cluster_stats(&addrs).unwrap();
        assert_eq!(stats.per_worker.len(), 2);
        let total = |key: &str| {
            stats
                .totals
                .iter()
                .find(|(k, _)| k == key)
                .unwrap_or_else(|| panic!("missing key {key}"))
                .1
        };
        // every worker trained once; the aggregated totals must match
        // the per-worker reports exactly (counters, not averaged rates)
        let iters: u64 = out.reports.iter().map(|r| r.iterations as u64).sum();
        assert_eq!(total("train_iterations"), iters);
        assert_eq!(total("solver_calls"), stats
            .per_worker
            .iter()
            .map(|(_, c)| c.iter().find(|(k, _)| k == "solver_calls").unwrap().1)
            .sum::<u64>());
        assert!(total("smo_iterations") > 0);
        w1.stop();
        w2.stop();
    }

    #[test]
    fn heartbeat_probe_reflects_liveness() {
        let mut w = WorkerServer::spawn("127.0.0.1:0").unwrap();
        let timeout = Duration::from_secs(5);
        assert!(heartbeat_probe(w.addr(), timeout));
        assert_eq!(w.metrics().heartbeats_served.get(), 1);
        // a fault-killed worker accepts and immediately drops: no ack
        let dead = WorkerServer::spawn_with_faults(
            "127.0.0.1:0",
            Some(FaultPlan::parse("kill_after=0").unwrap()),
        )
        .unwrap();
        assert!(!heartbeat_probe(dead.addr(), Duration::from_millis(500)));
        w.stop();
    }

    #[test]
    fn worker_state_machine_transitions() {
        let mut h = WorkerHealth::default();
        assert_eq!(h.state(), WorkerState::Healthy);
        // failure with a live heartbeat: benefit of the doubt
        h.on_failure(true);
        assert_eq!(h.state(), WorkerState::Suspect);
        // success resets
        h.on_success();
        assert_eq!(h.state(), WorkerState::Healthy);
        // two consecutive acked failures: dead
        h.on_failure(true);
        h.on_failure(true);
        assert_eq!(h.state(), WorkerState::Dead);
        // an unacked failure is immediately dead, from any state
        let mut h2 = WorkerHealth::default();
        h2.on_failure(false);
        assert_eq!(h2.state(), WorkerState::Dead);
    }

    #[test]
    fn retry_schedule_deterministic_growing_capped() {
        let sched = RetrySchedule::from_timeout(Duration::from_secs(8));
        assert_eq!(sched.base, Duration::from_secs(1));
        // deterministic: same (attempt, seed, shard) -> same delay
        for attempt in 0..5 {
            assert_eq!(sched.delay(attempt, 7, 3), sched.delay(attempt, 7, 3));
        }
        // exponential growth until the cap
        assert!(sched.delay(1, 7, 3) > sched.delay(0, 7, 3));
        assert!(sched.delay(2, 7, 3) > sched.delay(1, 7, 3));
        // capped at the worker timeout
        assert_eq!(sched.delay(30, 7, 3), Duration::from_secs(8));
        // jitter stays within [0, base/2)
        let d0 = sched.delay(0, 7, 3);
        assert!(d0 >= sched.base && d0 < sched.base + sched.base / 2, "{d0:?}");
        // different shards get different jitter (decorrelated retries)
        let spread: std::collections::BTreeSet<Duration> =
            (0..16).map(|s| sched.delay(0, 7, s)).collect();
        assert!(spread.len() > 1, "jitter collapsed: {spread:?}");
        // tiny timeouts still get a sane floor
        let tiny = RetrySchedule::from_timeout(Duration::from_millis(1));
        assert_eq!(tiny.base, Duration::from_millis(10));
        assert!(tiny.delay(0, 1, 1) >= tiny.base.min(tiny.cap));
    }
}
