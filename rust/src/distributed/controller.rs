//! Controller-side logic shared by the local and TCP transports:
//! sharding, SV-set union, the final combining solve, and run stats.

use crate::error::{Error, Result};
use crate::sampling::SamplingConfig;
use crate::svdd::model::SvddModel;
use crate::svdd::trainer::{train_detailed, SolverStats, SvddParams};
use crate::util::matrix::Matrix;
use crate::util::rng::Xoshiro256;

/// Distributed run configuration.
#[derive(Clone, Copy, Debug)]
pub struct DistributedConfig {
    /// Worker count `p`.
    pub workers: usize,
    pub sampling: SamplingConfig,
    pub seed: u64,
    /// Seeded pre-shuffle of the row order before contiguous sharding.
    /// `None` (the default) shards the rows as given — correct for the
    /// i.i.d. generators; pass `Some(seed)` when the dataset may be
    /// ordered (sorted by a feature, grouped by regime), where
    /// contiguous shards would hand each worker a biased slice.
    pub shuffle_seed: Option<u64>,
}

impl Default for DistributedConfig {
    fn default() -> Self {
        DistributedConfig {
            workers: 4,
            sampling: SamplingConfig::default(),
            seed: 0,
            shuffle_seed: None,
        }
    }
}

/// Per-worker report.
#[derive(Clone, Debug)]
pub struct WorkerReport {
    pub worker: usize,
    pub shard_rows: usize,
    pub sv_count: usize,
    pub iterations: usize,
    pub converged: bool,
}

/// Result of a distributed run.
#[derive(Clone, Debug)]
pub struct DistributedOutcome {
    pub model: SvddModel,
    pub reports: Vec<WorkerReport>,
    /// Rows in the union set S' the controller solved.
    pub union_rows: usize,
    /// SMO telemetry of the controller's final combining solve (the
    /// worker-side solves stay on the workers; their iteration counts
    /// travel in [`WorkerReport`]).
    pub solver: SolverStats,
}

/// Split `data` into `p` contiguous shards of near-equal size.
/// (Generators produce i.i.d. rows, so contiguous == random split;
/// ordered data wants [`shard_with_shuffle`] with a seed, which
/// permutes the rows first.)
pub fn shard(data: &Matrix, p: usize) -> Vec<Matrix> {
    shard_with_shuffle(data, p, None)
}

/// [`shard`] with an optional seeded Fisher–Yates pre-shuffle of the
/// row order (`DistributedConfig::shuffle_seed`). `None` preserves the
/// historical contiguous split exactly; `Some(seed)` deterministically
/// permutes the rows before slicing, so a dataset sorted by a feature
/// still gives every worker an unbiased sample. Shard sizes are
/// identical in both modes.
pub fn shard_with_shuffle(data: &Matrix, p: usize, shuffle_seed: Option<u64>) -> Vec<Matrix> {
    let mut order: Vec<usize> = (0..data.rows()).collect();
    if let Some(seed) = shuffle_seed {
        Xoshiro256::new(seed).shuffle(&mut order);
    }
    shard_order(data, p, &order)
}

fn shard_order(data: &Matrix, p: usize, order: &[usize]) -> Vec<Matrix> {
    let p = p.max(1).min(data.rows().max(1));
    let n = data.rows();
    let base = n / p;
    let extra = n % p;
    let mut shards = Vec::with_capacity(p);
    let mut start = 0;
    for i in 0..p {
        let len = base + usize::from(i < extra);
        shards.push(data.gather(&order[start..start + len]));
        start += len;
    }
    shards
}

/// Combine worker SV sets: union + dedup + one final SVDD (Fig 2's
/// controller box).
pub fn combine(
    sv_sets: Vec<Matrix>,
    params: &SvddParams,
) -> Result<(SvddModel, usize)> {
    combine_detailed(sv_sets, params).map(|(model, rows, _)| (model, rows))
}

/// [`combine`] with the final solve's SMO telemetry.
pub fn combine_detailed(
    sv_sets: Vec<Matrix>,
    params: &SvddParams,
) -> Result<(SvddModel, usize, SolverStats)> {
    let mut union: Option<Matrix> = None;
    for sv in sv_sets {
        union = Some(match union {
            None => sv,
            Some(u) => u.vstack(&sv)?,
        });
    }
    let union = union
        .ok_or_else(|| Error::Distributed("no worker SV sets to combine".into()))?
        .dedup_rows();
    let rows = union.rows();
    let (model, stats) = train_detailed(&union, params, None)?;
    Ok((model, rows, stats))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{banana::Banana, Generator};

    #[test]
    fn shard_sizes_balanced_and_complete() {
        let data = Banana::default().generate(103, 1);
        let shards = shard(&data, 4);
        assert_eq!(shards.len(), 4);
        let sizes: Vec<usize> = shards.iter().map(|s| s.rows()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 103);
        assert_eq!(sizes, vec![26, 26, 26, 25]);
    }

    #[test]
    fn shard_more_workers_than_rows() {
        let data = Banana::default().generate(3, 2);
        let shards = shard(&data, 10);
        assert_eq!(shards.len(), 3);
        assert!(shards.iter().all(|s| s.rows() == 1));
    }

    #[test]
    fn shuffle_none_preserves_contiguous_split_exactly() {
        let data = Banana::default().generate(103, 7);
        let plain = shard(&data, 4);
        let none = shard_with_shuffle(&data, 4, None);
        assert_eq!(plain.len(), none.len());
        for (a, b) in plain.iter().zip(&none) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn shuffle_fixes_sorted_dataset_sharding() {
        // a dataset sorted by its feature: contiguous shards are
        // disjoint value ranges, so per-shard means are wildly apart
        let rows: Vec<Vec<f64>> = (0..400).map(|i| vec![i as f64]).collect();
        let data = Matrix::from_rows(&rows).unwrap();
        let p = 4;

        let biased = shard_with_shuffle(&data, p, None);
        let mean = |s: &Matrix| s.col_means()[0];
        assert!(mean(&biased[0]) < 60.0 && mean(&biased[p - 1]) > 340.0);

        let shuffled = shard_with_shuffle(&data, p, Some(42));
        // sizes unchanged, all rows present exactly once
        let mut all: Vec<f64> = Vec::new();
        for (s, b) in shuffled.iter().zip(&biased) {
            assert_eq!(s.rows(), b.rows());
            all.extend(s.as_slice());
        }
        all.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(all, (0..400).map(|i| i as f64).collect::<Vec<_>>());
        // every shard now sees the full range: means near the global 199.5
        for s in &shuffled {
            let m = mean(s);
            assert!((m - 199.5).abs() < 60.0, "shard mean {m} still biased");
        }
        // deterministic given the seed
        let again = shard_with_shuffle(&data, p, Some(42));
        for (a, b) in shuffled.iter().zip(&again) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn combine_unions_and_solves() {
        let params = SvddParams::gaussian(0.35, 0.01);
        let a = Banana::default().generate(60, 3);
        let b = Banana::default().generate(60, 4);
        let (model, rows) = combine(vec![a.clone(), b], &params).unwrap();
        assert!(rows <= 120);
        assert!(model.num_sv() >= 3);
        // duplicate sets collapse
        let (_, rows2) = combine(vec![a.clone(), a.clone()], &params).unwrap();
        assert_eq!(rows2, 60);
    }

    #[test]
    fn combine_empty_rejected() {
        let params = SvddParams::gaussian(0.35, 0.01);
        assert!(combine(vec![], &params).is_err());
    }
}
