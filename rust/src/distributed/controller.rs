//! Controller-side logic shared by the local and TCP transports:
//! sharding, SV-set union, the final combining solve (flat or
//! fixed-fanout tree), and run stats.

use std::time::Duration;

use crate::error::{Error, Result};
use crate::sampling::SamplingConfig;
use crate::svdd::model::SvddModel;
use crate::svdd::trainer::{train_detailed, SolverStats, SvddParams};
use crate::util::matrix::Matrix;
use crate::util::rng::Xoshiro256;

/// Default tree-combine fanout (`--combine tree` without `:N`).
pub const DEFAULT_FANOUT: usize = 4;

/// How the controller combines worker SV sets into the final model.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum CombineMode {
    /// One union of every SV set and a single final solve (the paper's
    /// Fig 2 controller box). Byte-identical to the historical path.
    #[default]
    Flat,
    /// Union SV sets in groups of `fanout` up a tree, solving each
    /// group and promoting its SVs, so no single solve scales with the
    /// total SV count across all shards. Deterministic for a fixed
    /// (shard order, fanout); tolerance-equivalent to [`CombineMode::Flat`].
    Tree { fanout: usize },
}

impl CombineMode {
    /// Parse `"flat"`, `"tree"` (default fanout) or `"tree:N"`.
    pub fn parse(s: &str) -> Result<CombineMode> {
        match s.trim() {
            "flat" => Ok(CombineMode::Flat),
            "tree" => Ok(CombineMode::Tree { fanout: DEFAULT_FANOUT }),
            t => {
                let fanout = t
                    .strip_prefix("tree:")
                    .and_then(|n| n.parse::<usize>().ok())
                    .ok_or_else(|| {
                        Error::Config(format!("combine mode '{t}': expected flat|tree|tree:N"))
                    })?;
                if fanout < 2 {
                    return Err(Error::Config(format!(
                        "combine fanout must be >= 2, got {fanout}"
                    )));
                }
                Ok(CombineMode::Tree { fanout })
            }
        }
    }
}

impl std::fmt::Display for CombineMode {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            CombineMode::Flat => write!(f, "flat"),
            CombineMode::Tree { fanout } => write!(f, "tree:{fanout}"),
        }
    }
}

/// Distributed run configuration.
#[derive(Clone, Copy, Debug)]
pub struct DistributedConfig {
    /// Worker count `p`.
    pub workers: usize,
    pub sampling: SamplingConfig,
    pub seed: u64,
    /// Seeded pre-shuffle of the row order before contiguous sharding.
    /// `None` (the default) shards the rows as given — correct for the
    /// i.i.d. generators; pass `Some(seed)` when the dataset may be
    /// ordered (sorted by a feature, grouped by regime), where
    /// contiguous shards would hand each worker a biased slice.
    pub shuffle_seed: Option<u64>,
    /// Retry budget per shard beyond its first attempt (TCP transport).
    /// A shard that fails `max_retries + 1` times fails the run.
    pub max_retries: usize,
    /// Per-attempt socket deadline (`connect`/`read`/`write`) on every
    /// controller↔worker connection; also paces the liveness probes. A
    /// worker that keeps acking heartbeats is granted bounded read
    /// extensions while a long solve runs (see `distributed::tcp`).
    pub worker_timeout: Duration,
    /// When fewer than this many workers remain alive (but at least
    /// one), remaining shards are trained locally in the controller
    /// instead of being shipped to the depleted cluster. Zero live
    /// workers always fails the run with `Error::Distributed`.
    pub min_workers: usize,
    /// SV-set combine strategy for the final model.
    pub combine: CombineMode,
}

impl Default for DistributedConfig {
    fn default() -> Self {
        DistributedConfig {
            workers: 4,
            sampling: SamplingConfig::default(),
            seed: 0,
            shuffle_seed: None,
            max_retries: 2,
            worker_timeout: Duration::from_secs(30),
            min_workers: 1,
            combine: CombineMode::Flat,
        }
    }
}

/// Per-worker report.
#[derive(Clone, Debug)]
pub struct WorkerReport {
    pub worker: usize,
    pub shard_rows: usize,
    pub sv_count: usize,
    pub iterations: usize,
    pub converged: bool,
}

/// Fault-tolerance accounting for one distributed run. All zeros on a
/// clean run (and always on the in-process local transport, which has
/// no failure domain to retry across).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct RetryStats {
    /// Shard attempts that failed and re-entered the work queue.
    pub shard_retries: u64,
    /// Retried shards that ran on a different worker than the attempt
    /// that failed.
    pub shards_reassigned: u64,
    /// Individual worker-attempt failures (timeouts, dropped
    /// connections, corrupt frames, `TrainFailed` replies).
    pub worker_failures: u64,
    /// Workers the state machine declared dead.
    pub workers_lost: u64,
    /// Shards the controller trained locally after the live worker set
    /// fell below `min_workers`.
    pub shards_local_fallback: u64,
}

/// Result of a distributed run.
#[derive(Clone, Debug)]
pub struct DistributedOutcome {
    pub model: SvddModel,
    pub reports: Vec<WorkerReport>,
    /// Rows in the union set S' the controller solved (for tree mode:
    /// rows of the root solve).
    pub union_rows: usize,
    /// SMO telemetry of the controller's combining solve(s), absorbed
    /// across every tree level in tree mode (the worker-side solves
    /// stay on the workers; their iteration counts travel in
    /// [`WorkerReport`]).
    pub solver: SolverStats,
    /// Combining solves performed (1 for flat; one per tree node).
    pub combine_solves: usize,
    /// Retry / failure accounting (zeros on a clean run).
    pub retry: RetryStats,
}

/// Split `data` into `p` contiguous shards of near-equal size.
/// (Generators produce i.i.d. rows, so contiguous == random split;
/// ordered data wants [`shard_with_shuffle`] with a seed, which
/// permutes the rows first.)
pub fn shard(data: &Matrix, p: usize) -> Vec<Matrix> {
    shard_with_shuffle(data, p, None)
}

/// [`shard`] with an optional seeded Fisher–Yates pre-shuffle of the
/// row order (`DistributedConfig::shuffle_seed`). `None` preserves the
/// historical contiguous split exactly; `Some(seed)` deterministically
/// permutes the rows before slicing, so a dataset sorted by a feature
/// still gives every worker an unbiased sample. Shard sizes are
/// identical in both modes.
pub fn shard_with_shuffle(data: &Matrix, p: usize, shuffle_seed: Option<u64>) -> Vec<Matrix> {
    let mut order: Vec<usize> = (0..data.rows()).collect();
    if let Some(seed) = shuffle_seed {
        Xoshiro256::new(seed).shuffle(&mut order);
    }
    shard_order(data, p, &order)
}

fn shard_order(data: &Matrix, p: usize, order: &[usize]) -> Vec<Matrix> {
    let p = p.max(1).min(data.rows().max(1));
    let n = data.rows();
    let base = n / p;
    let extra = n % p;
    let mut shards = Vec::with_capacity(p);
    let mut start = 0;
    for i in 0..p {
        let len = base + usize::from(i < extra);
        shards.push(data.gather(&order[start..start + len]));
        start += len;
    }
    shards
}

/// Combine worker SV sets: union + dedup + one final SVDD (Fig 2's
/// controller box).
pub fn combine(
    sv_sets: Vec<Matrix>,
    params: &SvddParams,
) -> Result<(SvddModel, usize)> {
    combine_detailed(sv_sets, params).map(|(model, rows, _)| (model, rows))
}

/// [`combine`] with the final solve's SMO telemetry.
pub fn combine_detailed(
    sv_sets: Vec<Matrix>,
    params: &SvddParams,
) -> Result<(SvddModel, usize, SolverStats)> {
    let mut union: Option<Matrix> = None;
    for sv in sv_sets {
        union = Some(match union {
            None => sv,
            Some(u) => u.vstack(&sv)?,
        });
    }
    let union = union
        .ok_or_else(|| Error::Distributed("no worker SV sets to combine".into()))?
        .dedup_rows();
    let rows = union.rows();
    let (model, stats) = train_detailed(&union, params, None)?;
    Ok((model, rows, stats))
}

/// Dispatch on [`CombineMode`]. Returns the model, the rows of the
/// final (root) solve, solver telemetry absorbed across every solve,
/// and the number of combining solves performed. `Flat` is exactly
/// [`combine_detailed`] — same code path, byte-identical model.
pub fn combine_with_mode(
    sv_sets: Vec<Matrix>,
    params: &SvddParams,
    mode: CombineMode,
) -> Result<(SvddModel, usize, SolverStats, usize)> {
    let mut span = crate::obs::Span::enter("distributed.combine");
    let sets = sv_sets.len();
    let out = match mode {
        CombineMode::Flat => {
            combine_detailed(sv_sets, params).map(|(model, rows, stats)| (model, rows, stats, 1))
        }
        CombineMode::Tree { fanout } => combine_tree(sv_sets, params, fanout),
    }?;
    if span.is_live() {
        span.str("mode", mode.to_string());
        span.u64("sets", sets as u64);
        span.u64("union_rows", out.1 as u64);
        span.u64("solves", out.3 as u64);
    }
    Ok(out)
}

/// Hierarchical combine: union SV sets in consecutive groups of
/// `fanout`, solve each group and promote its SVs to the next level,
/// until at most `fanout` sets remain — those get the flat treatment
/// (one union, one final solve), so with `sv_sets.len() <= fanout` the
/// tree degenerates to [`combine_detailed`] exactly. Grouping is by
/// position, so the result is deterministic for a fixed shard order
/// and fanout.
pub fn combine_tree(
    sv_sets: Vec<Matrix>,
    params: &SvddParams,
    fanout: usize,
) -> Result<(SvddModel, usize, SolverStats, usize)> {
    if sv_sets.is_empty() {
        return Err(Error::Distributed("no worker SV sets to combine".into()));
    }
    let fanout = fanout.max(2);
    let mut agg = SolverStats::default();
    let mut solves = 0usize;
    let mut level = sv_sets;
    loop {
        if level.len() <= fanout {
            let (model, rows, stats) = combine_detailed(level, params)?;
            agg.absorb(&stats);
            solves += 1;
            return Ok((model, rows, agg, solves));
        }
        let mut next = Vec::with_capacity((level.len() + fanout - 1) / fanout);
        for group in level.chunks(fanout) {
            let mut union = group[0].clone();
            for sv in &group[1..] {
                union = union.vstack(sv)?;
            }
            let union = union.dedup_rows();
            let (model, stats) = train_detailed(&union, params, None)?;
            agg.absorb(&stats);
            solves += 1;
            next.push(model.support_vectors().clone());
        }
        level = next;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{banana::Banana, Generator};

    #[test]
    fn shard_sizes_balanced_and_complete() {
        let data = Banana::default().generate(103, 1);
        let shards = shard(&data, 4);
        assert_eq!(shards.len(), 4);
        let sizes: Vec<usize> = shards.iter().map(|s| s.rows()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 103);
        assert_eq!(sizes, vec![26, 26, 26, 25]);
    }

    #[test]
    fn shard_more_workers_than_rows() {
        let data = Banana::default().generate(3, 2);
        let shards = shard(&data, 10);
        assert_eq!(shards.len(), 3);
        assert!(shards.iter().all(|s| s.rows() == 1));
    }

    #[test]
    fn shuffle_none_preserves_contiguous_split_exactly() {
        let data = Banana::default().generate(103, 7);
        let plain = shard(&data, 4);
        let none = shard_with_shuffle(&data, 4, None);
        assert_eq!(plain.len(), none.len());
        for (a, b) in plain.iter().zip(&none) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn shuffle_fixes_sorted_dataset_sharding() {
        // a dataset sorted by its feature: contiguous shards are
        // disjoint value ranges, so per-shard means are wildly apart
        let rows: Vec<Vec<f64>> = (0..400).map(|i| vec![i as f64]).collect();
        let data = Matrix::from_rows(&rows).unwrap();
        let p = 4;

        let biased = shard_with_shuffle(&data, p, None);
        let mean = |s: &Matrix| s.col_means()[0];
        assert!(mean(&biased[0]) < 60.0 && mean(&biased[p - 1]) > 340.0);

        let shuffled = shard_with_shuffle(&data, p, Some(42));
        // sizes unchanged, all rows present exactly once
        let mut all: Vec<f64> = Vec::new();
        for (s, b) in shuffled.iter().zip(&biased) {
            assert_eq!(s.rows(), b.rows());
            all.extend(s.as_slice());
        }
        all.sort_by(|a, b| a.partial_cmp(b).unwrap());
        assert_eq!(all, (0..400).map(|i| i as f64).collect::<Vec<_>>());
        // every shard now sees the full range: means near the global 199.5
        for s in &shuffled {
            let m = mean(s);
            assert!((m - 199.5).abs() < 60.0, "shard mean {m} still biased");
        }
        // deterministic given the seed
        let again = shard_with_shuffle(&data, p, Some(42));
        for (a, b) in shuffled.iter().zip(&again) {
            assert_eq!(a, b);
        }
    }

    #[test]
    fn combine_unions_and_solves() {
        let params = SvddParams::gaussian(0.35, 0.01);
        let a = Banana::default().generate(60, 3);
        let b = Banana::default().generate(60, 4);
        let (model, rows) = combine(vec![a.clone(), b], &params).unwrap();
        assert!(rows <= 120);
        assert!(model.num_sv() >= 3);
        // duplicate sets collapse
        let (_, rows2) = combine(vec![a.clone(), a.clone()], &params).unwrap();
        assert_eq!(rows2, 60);
    }

    #[test]
    fn combine_empty_rejected() {
        let params = SvddParams::gaussian(0.35, 0.01);
        assert!(combine(vec![], &params).is_err());
        assert!(combine_tree(vec![], &params, 2).is_err());
    }

    #[test]
    fn combine_mode_parses_and_displays() {
        assert_eq!(CombineMode::parse("flat").unwrap(), CombineMode::Flat);
        assert_eq!(
            CombineMode::parse("tree").unwrap(),
            CombineMode::Tree { fanout: DEFAULT_FANOUT }
        );
        assert_eq!(CombineMode::parse("tree:8").unwrap(), CombineMode::Tree { fanout: 8 });
        assert_eq!(CombineMode::parse("tree:8").unwrap().to_string(), "tree:8");
        assert_eq!(CombineMode::default(), CombineMode::Flat);
        assert!(CombineMode::parse("pyramid").is_err());
        assert!(CombineMode::parse("tree:1").is_err());
        assert!(CombineMode::parse("tree:x").is_err());
    }

    #[test]
    fn tree_with_few_sets_degenerates_to_flat() {
        // <= fanout sets take the single-union path, so the model is
        // bit-identical to the flat combine
        let params = SvddParams::gaussian(0.35, 0.01);
        let sets: Vec<Matrix> =
            (0..3).map(|i| Banana::default().generate(40, 10 + i)).collect();
        let (flat, flat_rows, _) = combine_detailed(sets.clone(), &params).unwrap();
        let (tree, tree_rows, _, solves) = combine_tree(sets, &params, 4).unwrap();
        assert_eq!(solves, 1);
        assert_eq!(flat_rows, tree_rows);
        assert_eq!(flat.support_vectors(), tree.support_vectors());
        assert_eq!(flat.r2(), tree.r2());
    }

    #[test]
    fn tree_combine_is_deterministic_and_tolerance_equivalent() {
        let params = SvddParams::gaussian(0.35, 0.01);
        let sets: Vec<Matrix> =
            (0..9).map(|i| Banana::default().generate(40, 20 + i)).collect();
        let (flat, _, _) = combine_detailed(sets.clone(), &params).unwrap();
        let (tree, _, _, solves) = combine_tree(sets.clone(), &params, 2).unwrap();
        // 9 -> 5 -> 3 -> 2 -> root: 4+2+1 internal + 1 final... exact
        // count depends only on (n, fanout); just pin it is multi-level
        assert!(solves > 1, "9 sets at fanout 2 must build a real tree");
        let rel = (tree.r2() - flat.r2()).abs() / flat.r2();
        assert!(rel < 0.05, "tree r2 {} vs flat {} (rel {rel})", tree.r2(), flat.r2());
        // deterministic given (shard order, fanout)
        let (again, _, _, solves2) = combine_tree(sets, &params, 2).unwrap();
        assert_eq!(solves, solves2);
        assert_eq!(tree.support_vectors(), again.support_vectors());
        assert_eq!(tree.r2(), again.r2());
    }
}
