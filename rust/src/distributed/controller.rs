//! Controller-side logic shared by the local and TCP transports:
//! sharding, SV-set union, the final combining solve, and run stats.

use crate::error::{Error, Result};
use crate::sampling::SamplingConfig;
use crate::svdd::model::SvddModel;
use crate::svdd::trainer::{train, SvddParams};
use crate::util::matrix::Matrix;

/// Distributed run configuration.
#[derive(Clone, Copy, Debug)]
pub struct DistributedConfig {
    /// Worker count `p`.
    pub workers: usize,
    pub sampling: SamplingConfig,
    pub seed: u64,
}

impl Default for DistributedConfig {
    fn default() -> Self {
        DistributedConfig {
            workers: 4,
            sampling: SamplingConfig::default(),
            seed: 0,
        }
    }
}

/// Per-worker report.
#[derive(Clone, Debug)]
pub struct WorkerReport {
    pub worker: usize,
    pub shard_rows: usize,
    pub sv_count: usize,
    pub iterations: usize,
    pub converged: bool,
}

/// Result of a distributed run.
#[derive(Clone, Debug)]
pub struct DistributedOutcome {
    pub model: SvddModel,
    pub reports: Vec<WorkerReport>,
    /// Rows in the union set S' the controller solved.
    pub union_rows: usize,
}

/// Split `data` into `p` contiguous shards of near-equal size.
/// (Generators produce i.i.d. rows, so contiguous == random split; data
/// with ordered rows should be shuffled upstream.)
pub fn shard(data: &Matrix, p: usize) -> Vec<Matrix> {
    let p = p.max(1).min(data.rows().max(1));
    let n = data.rows();
    let base = n / p;
    let extra = n % p;
    let mut shards = Vec::with_capacity(p);
    let mut start = 0;
    for i in 0..p {
        let len = base + usize::from(i < extra);
        let idx: Vec<usize> = (start..start + len).collect();
        shards.push(data.gather(&idx));
        start += len;
    }
    shards
}

/// Combine worker SV sets: union + dedup + one final SVDD (Fig 2's
/// controller box).
pub fn combine(
    sv_sets: Vec<Matrix>,
    params: &SvddParams,
) -> Result<(SvddModel, usize)> {
    let mut union: Option<Matrix> = None;
    for sv in sv_sets {
        union = Some(match union {
            None => sv,
            Some(u) => u.vstack(&sv)?,
        });
    }
    let union = union
        .ok_or_else(|| Error::Distributed("no worker SV sets to combine".into()))?
        .dedup_rows();
    let rows = union.rows();
    let model = train(&union, params)?;
    Ok((model, rows))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::{banana::Banana, Generator};

    #[test]
    fn shard_sizes_balanced_and_complete() {
        let data = Banana::default().generate(103, 1);
        let shards = shard(&data, 4);
        assert_eq!(shards.len(), 4);
        let sizes: Vec<usize> = shards.iter().map(|s| s.rows()).collect();
        assert_eq!(sizes.iter().sum::<usize>(), 103);
        assert_eq!(sizes, vec![26, 26, 26, 25]);
    }

    #[test]
    fn shard_more_workers_than_rows() {
        let data = Banana::default().generate(3, 2);
        let shards = shard(&data, 10);
        assert_eq!(shards.len(), 3);
        assert!(shards.iter().all(|s| s.rows() == 1));
    }

    #[test]
    fn combine_unions_and_solves() {
        let params = SvddParams::gaussian(0.35, 0.01);
        let a = Banana::default().generate(60, 3);
        let b = Banana::default().generate(60, 4);
        let (model, rows) = combine(vec![a.clone(), b], &params).unwrap();
        assert!(rows <= 120);
        assert!(model.num_sv() >= 3);
        // duplicate sets collapse
        let (_, rows2) = combine(vec![a.clone(), a.clone()], &params).unwrap();
        assert_eq!(rows2, 60);
    }

    #[test]
    fn combine_empty_rejected() {
        let params = SvddParams::gaussian(0.35, 0.01);
        assert!(combine(vec![], &params).is_err());
    }
}
