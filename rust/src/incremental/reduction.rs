//! Boundary-preserving sample reduction (Englhardt et al., arXiv
//! 2009.13853 flavor): keep the rows that *shape* the decision
//! boundary, drop the deep-interior mass that only slows the solver.
//!
//! A pilot model trained on a uniform subsample estimates the
//! boundary; every row is then scored on the norm-cached block path
//! ([`SvddModel::dist2_batch`]) and ranked by `|dist² - R²|` — its
//! distance to the pilot boundary shell. The `target` nearest rows are
//! kept and handed to the ordinary batch solver. Compared to the
//! paper's uniform sampling this buys a much smaller training set at
//! equal boundary fidelity, at the price of one pilot solve plus one
//! full scoring pass.

use crate::error::{Error, Result};
use crate::svdd::trainer::{train_detailed, SolverStats, SvddParams};
use crate::svdd::SvddModel;
use crate::util::matrix::Matrix;
use crate::util::rng::Xoshiro256;

use super::ReductionConfig;

/// What the reduction pass decided.
#[derive(Clone, Debug)]
pub struct ReductionOutcome {
    /// Kept row indices into the original data, ascending (original
    /// row order is preserved for the final solve).
    pub kept: Vec<usize>,
    /// Rows the pilot model was trained on (0 when reduction was a
    /// no-op because `target >= n`).
    pub pilot_size: usize,
    /// `|dist² - R²|` of the farthest kept row — the half-width of the
    /// boundary shell the kept set spans.
    pub shell_width: f64,
    /// Pilot solve telemetry.
    pub pilot_solver: SolverStats,
}

fn effective_target(cfg: &ReductionConfig, n: usize) -> usize {
    if cfg.target > 0 {
        cfg.target.min(n)
    } else {
        (n / 10).max(50).min(n)
    }
}

/// Pick the boundary-preserving subset. Deterministic given `seed`.
pub fn reduce(
    data: &Matrix,
    params: &SvddParams,
    cfg: &ReductionConfig,
    seed: u64,
) -> Result<ReductionOutcome> {
    let n = data.rows();
    if n == 0 {
        return Err(Error::invalid("reduction: empty training set"));
    }
    let target = effective_target(cfg, n);
    if target >= n {
        return Ok(ReductionOutcome {
            kept: (0..n).collect(),
            pilot_size: 0,
            shell_width: 0.0,
            pilot_solver: SolverStats::default(),
        });
    }
    let pilot_n = if cfg.pilot > 0 { cfg.pilot.min(n) } else { target.max(128).min(n) };
    let mut rng = Xoshiro256::new(seed);
    let mut idx = rng.sample_with_replacement(n, pilot_n);
    idx.sort_unstable();
    idx.dedup();
    let pilot_data = data.gather(&idx).dedup_rows();
    let (pilot, pilot_solver) = train_detailed(&pilot_data, params, None)?;
    let d2 = pilot.dist2_batch(data);
    let r2 = pilot.r2();
    let mut order: Vec<usize> = (0..n).collect();
    order.sort_by(|&a, &b| {
        let sa = (d2[a] - r2).abs();
        let sb = (d2[b] - r2).abs();
        sa.partial_cmp(&sb).unwrap_or(std::cmp::Ordering::Equal).then(a.cmp(&b))
    });
    let shell_width = (d2[order[target - 1]] - r2).abs();
    let mut kept = order[..target].to_vec();
    kept.sort_unstable();
    Ok(ReductionOutcome {
        kept,
        pilot_size: pilot_data.rows(),
        shell_width,
        pilot_solver,
    })
}

/// [`reduce`], then solve on the kept rows. The returned stats fold
/// the pilot and final solves together.
pub fn reduce_and_train(
    data: &Matrix,
    params: &SvddParams,
    cfg: &ReductionConfig,
    seed: u64,
) -> Result<(SvddModel, SolverStats, ReductionOutcome)> {
    let outcome = reduce(data, params, cfg, seed)?;
    let reduced = data.gather(&outcome.kept);
    let (model, final_stats) = train_detailed(&reduced, params, None)?;
    let mut stats = outcome.pilot_solver;
    stats.absorb(&final_stats);
    Ok((model, stats, outcome))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::rng::Xoshiro256;

    fn ring(n: usize, seed: u64) -> Matrix {
        let mut rng = Xoshiro256::new(seed);
        let rows: Vec<Vec<f64>> = (0..n)
            .map(|_| {
                let th = rng.range(0.0, std::f64::consts::TAU);
                let r = rng.range(0.8, 1.2);
                vec![r * th.cos(), r * th.sin()]
            })
            .collect();
        Matrix::from_rows(&rows).unwrap()
    }

    #[test]
    fn reduction_keeps_target_rows_in_order() {
        let data = ring(400, 1);
        let params = SvddParams::gaussian(0.6, 0.05);
        let cfg = ReductionConfig { target: 80, pilot: 100 };
        let out = reduce(&data, &params, &cfg, 7).unwrap();
        assert_eq!(out.kept.len(), 80);
        assert!(out.kept.windows(2).all(|w| w[0] < w[1]), "kept not ascending");
        assert!(*out.kept.last().unwrap() < 400);
        assert!(out.pilot_size > 0);
        assert!(out.shell_width.is_finite());
    }

    #[test]
    fn reduction_is_noop_when_target_covers_everything() {
        let data = ring(40, 2);
        let params = SvddParams::gaussian(0.6, 0.05);
        let cfg = ReductionConfig { target: 100, pilot: 0 };
        let out = reduce(&data, &params, &cfg, 7).unwrap();
        assert_eq!(out.kept.len(), 40);
        assert_eq!(out.pilot_size, 0);
    }

    #[test]
    fn reduced_model_tracks_full_model_boundary() {
        let data = ring(500, 3);
        let params = SvddParams::gaussian(0.6, 0.02);
        let full = crate::svdd::trainer::train(&data, &params).unwrap();
        let cfg = ReductionConfig { target: 120, pilot: 150 };
        let (reduced, _, out) = reduce_and_train(&data, &params, &cfg, 11).unwrap();
        assert_eq!(out.kept.len(), 120);
        let rel = (reduced.r2() - full.r2()).abs() / full.r2();
        assert!(rel < 0.25, "reduced r2 {} vs full {}", reduced.r2(), full.r2());
        // the reduced boundary must agree with the full one on test
        // points: inside stays inside, far outside stays outside
        assert_eq!(reduced.is_outlier(&[5.0, 5.0]), true);
        assert_eq!(full.is_outlier(&[5.0, 5.0]), true);
        assert_eq!(reduced.is_outlier(&[1.0, 0.0]), false);
    }

    #[test]
    fn reduction_deterministic_given_seed() {
        let data = ring(300, 4);
        let params = SvddParams::gaussian(0.6, 0.05);
        let cfg = ReductionConfig { target: 60, pilot: 0 };
        let a = reduce(&data, &params, &cfg, 5).unwrap();
        let b = reduce(&data, &params, &cfg, 5).unwrap();
        assert_eq!(a.kept, b.kept);
        let c = reduce(&data, &params, &cfg, 6).unwrap();
        assert!(a.kept != c.kept || a.pilot_size != c.pilot_size);
    }
}
