//! Online-learning subsystem: exact incremental SVDD and
//! boundary-preserving sample reduction.
//!
//! Two complementary answers to "the data moved, now what?":
//!
//! - [`IncrementalSvdd`] — a Jiang & Wang-style (arXiv 1709.00139)
//!   state machine that keeps the dual solution *exactly* optimal
//!   under per-point `add_point` / `remove_point` updates. The Gram
//!   matrix, dual vector and KKT gradient of the active set are
//!   maintained in place; each update costs O(k·d) kernel work plus a
//!   short maximal-violating-pair migration loop that walks variables
//!   between the interior / boundary-SV / bound-SV sets until the
//!   duality gap closes. A full warm-started re-solve ("resync") runs
//!   when the migration loop diverges or a configurable staleness
//!   budget is spent, bounding numerical drift.
//! - [`reduction`] — an Englhardt et al.-style (arXiv 2009.13853)
//!   boundary-preserving sample reduction: a pilot model estimates the
//!   decision boundary, every row is scored on the norm-cached block
//!   path, and only the rows nearest the boundary are kept for the
//!   final solve. A principled rival to the paper's uniform sampling
//!   when a one-shot reduced training set is wanted.
//!
//! Both are wired into the unified engine as
//! [`Method::Incremental`](crate::config::Method) and
//! [`Method::Reduction`](crate::config::Method), and the incremental
//! path additionally drives [`crate::sampling::StreamingSvdd`] (opt-in
//! per-point window mode) and
//! [`crate::registry::Lifecycle::respond`] (drift response without a
//! full retrain).

pub mod online;
pub mod reduction;

pub use online::{IncrementalSvdd, KktSet};
pub use reduction::{reduce, reduce_and_train, ReductionOutcome};

use std::collections::VecDeque;

/// Knobs for [`IncrementalSvdd`].
#[derive(Clone, Copy, Debug)]
pub struct IncrementalConfig {
    /// Force a full re-solve of the active set after this many
    /// add/remove updates (0 = resync only on divergence or by hand).
    /// The budget bounds floating-point drift in the maintained
    /// gradient: between resyncs every update is exact up to the
    /// migration-loop tolerance, and the resync re-derives the
    /// gradient from scratch.
    pub stale_budget: usize,
    /// Duality gap above which an exhausted migration loop counts as
    /// diverged and triggers an immediate resync.
    pub divergence_tol: f64,
    /// Migration-step cap per update (0 = auto: 64 x active points).
    pub adjust_iters: usize,
    /// Active-set bound honored by the `Method::Incremental` trainer's
    /// sliding ingestion (0 = unbounded). The state machine itself
    /// never evicts — callers decide what leaves the window.
    pub max_points: usize,
}

impl Default for IncrementalConfig {
    fn default() -> Self {
        IncrementalConfig {
            stale_budget: 64,
            divergence_tol: 1e-3,
            adjust_iters: 0,
            max_points: 2048,
        }
    }
}

/// Knobs for the boundary-preserving [`reduction`] pass.
#[derive(Clone, Copy, Debug)]
pub struct ReductionConfig {
    /// Rows to keep (0 = auto: `max(50, n/10)`).
    pub target: usize,
    /// Pilot subsample size for the boundary estimate (0 = auto:
    /// `max(target, 128)`, capped at `n`).
    pub pilot: usize,
}

impl Default for ReductionConfig {
    fn default() -> Self {
        ReductionConfig { target: 0, pilot: 0 }
    }
}

/// Insertion-order view over [`IncrementalSvdd`]'s swap-remove index
/// space, for callers sliding a FIFO window: `remove_point(i)` moves
/// the last point into slot `i`, and this ledger keeps "which slot is
/// oldest" correct across that swap.
#[derive(Clone, Debug, Default)]
pub struct InsertionOrder {
    order: VecDeque<usize>,
}

impl InsertionOrder {
    pub fn new() -> InsertionOrder {
        InsertionOrder { order: VecDeque::new() }
    }

    pub fn len(&self) -> usize {
        self.order.len()
    }

    pub fn is_empty(&self) -> bool {
        self.order.is_empty()
    }

    /// Note that a point was added at slot `idx` (always the current
    /// set size at add time).
    pub fn record_add(&mut self, idx: usize) {
        self.order.push_back(idx);
    }

    /// Slot of the oldest surviving point.
    pub fn oldest(&self) -> Option<usize> {
        self.order.front().copied()
    }

    /// Note a swap-removal: the point at `removed` left the set and
    /// the point previously at slot `last` now lives at `removed`.
    pub fn record_swap_remove(&mut self, removed: usize, last: usize) {
        if let Some(pos) = self.order.iter().position(|&v| v == removed) {
            self.order.remove(pos);
        }
        if removed != last {
            for v in self.order.iter_mut() {
                if *v == last {
                    *v = removed;
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn insertion_order_tracks_swap_removes() {
        let mut w = InsertionOrder::new();
        for i in 0..4 {
            w.record_add(i); // slots 0..4, oldest = 0
        }
        assert_eq!(w.oldest(), Some(0));
        // remove slot 0: point from slot 3 moves into 0
        w.record_swap_remove(0, 3);
        assert_eq!(w.len(), 3);
        assert_eq!(w.oldest(), Some(1));
        // the newest point (added last) must now be known as slot 0
        assert_eq!(*w.order.back().unwrap(), 0);
        // remove the new oldest (slot 1); the point at slot 2 moves in
        w.record_swap_remove(1, 2);
        assert_eq!(w.oldest(), Some(1));
        assert_eq!(w.len(), 2);
    }
}
