//! Exact incremental SVDD: per-point add/remove updates that keep the
//! dual solution at KKT optimality without a cold re-solve.
//!
//! The state machine maintains, for the current active set of `k`
//! points:
//!
//! - the Gaussian Gram matrix (stride-`cap` storage so adds and
//!   swap-removes touch O(k) entries, never a full O(k²) rebuild),
//! - the dual vector `a` (simplex-constrained: `sum a = 1`,
//!   `0 <= a_i <= C` with `C = 1/(k f)`), and
//! - the KKT gradient `g_i = 2 (K a)_i - K_ii` (so `dist²(x_i) =
//!   quad - g_i`, the same identity the batch solver uses).
//!
//! An **add** appends a zero-mass variable (one O(k·d) kernel column,
//! gradients untouched); a **remove** retires the departing mass from
//! every gradient entry and hands it back to the remaining variables.
//! Either way the box bound `C = 1/(k f)` moved, so an *adjust* pass
//! re-clamps, repairs the simplex sum, then runs maximal-violating-pair
//! migration steps — the Jiang & Wang (arXiv 1709.00139) set walks
//! between interior / boundary-SV / bound-SV — until the duality gap
//! closes to the solver tolerance. Every step is an exact coordinate
//! update on the maintained Gram, so between resyncs the solution is
//! optimal up to that tolerance, not an approximation.
//!
//! A **resync** (full warm-started SMO solve over the active set's
//! Gram) re-derives the gradient from scratch; it fires when the
//! migration loop diverges past [`IncrementalConfig::divergence_tol`]
//! or the [`IncrementalConfig::stale_budget`] is spent, bounding
//! floating-point drift over long update streams.

use crate::error::{Error, Result};
use crate::obs::Value;
use crate::svdd::smo::{self, DenseKernel};
use crate::svdd::trainer::{SolverStats, SvddParams};
use crate::svdd::SvddModel;
use crate::util::matrix::Matrix;

use super::IncrementalConfig;

/// Which KKT set a dual variable sits in.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum KktSet {
    /// `a = 0`: strictly inside the ball (non-SV).
    Interior,
    /// `0 < a < C`: boundary support vector.
    Boundary,
    /// `a = C`: bound support vector (described outlier).
    Outlier,
}

fn classify(a: f64, c: f64, eps: f64) -> KktSet {
    if a <= eps {
        KktSet::Interior
    } else if a >= c - eps {
        KktSet::Outlier
    } else {
        KktSet::Boundary
    }
}

/// Online SVDD over a mutable active set. See the module docs for the
/// maintained invariants.
#[derive(Clone, Debug)]
pub struct IncrementalSvdd {
    params: SvddParams,
    cfg: IncrementalConfig,
    points: Vec<Vec<f64>>,
    norms: Vec<f64>,
    /// Gram over the active set, entry `(i, j)` at `i * cap + j`. The
    /// stride is the allocation capacity, so adds write one row/col
    /// and swap-removes move one row/col.
    gram: Vec<f64>,
    cap: usize,
    alpha: Vec<f64>,
    g: Vec<f64>,
    last_gap: f64,
    updates: u64,
    resyncs: u64,
    migrations: u64,
    since_resync: usize,
    solver: SolverStats,
}

impl IncrementalSvdd {
    /// Empty state machine; feed it with [`IncrementalSvdd::add_point`].
    pub fn new(params: SvddParams, cfg: IncrementalConfig) -> IncrementalSvdd {
        IncrementalSvdd {
            params,
            cfg,
            points: Vec::new(),
            norms: Vec::new(),
            gram: Vec::new(),
            cap: 0,
            alpha: Vec::new(),
            g: Vec::new(),
            last_gap: 0.0,
            updates: 0,
            resyncs: 0,
            migrations: 0,
            since_resync: 0,
            solver: SolverStats::default(),
        }
    }

    /// Seed from a batch: builds the Gram and runs one cold solve (the
    /// seed counts as a resync in the stats). The seed solution is the
    /// same cold SMO solve a batch gram train would produce.
    pub fn with_data(
        params: SvddParams,
        cfg: IncrementalConfig,
        data: &Matrix,
    ) -> Result<IncrementalSvdd> {
        if data.rows() == 0 {
            return Err(Error::invalid("incremental seed needs at least one row"));
        }
        let mut inc = IncrementalSvdd::new(params, cfg);
        let n = data.rows();
        inc.ensure_cap(n);
        for i in 0..n {
            let row = data.row(i);
            inc.points.push(row.to_vec());
            inc.norms.push(crate::linalg::dot(row, row));
        }
        for i in 0..n {
            for j in 0..=i {
                let v = if i == j {
                    params.kernel.diag_from_norm(inc.norms[i])
                } else {
                    params.kernel.eval_cached(
                        &inc.points[i],
                        inc.norms[i],
                        &inc.points[j],
                        inc.norms[j],
                    )
                };
                inc.gram[i * inc.cap + j] = v;
                inc.gram[j * inc.cap + i] = v;
            }
        }
        inc.alpha = vec![0.0; n];
        inc.g = vec![0.0; n];
        inc.solve_active(None, "seed")?;
        Ok(inc)
    }

    pub fn len(&self) -> usize {
        self.points.len()
    }

    pub fn is_empty(&self) -> bool {
        self.points.is_empty()
    }

    /// Dimensionality of the active points (None while empty).
    pub fn dim(&self) -> Option<usize> {
        self.points.first().map(|p| p.len())
    }

    /// Add/remove updates applied so far.
    pub fn updates(&self) -> u64 {
        self.updates
    }

    /// Full re-solves (seed, staleness, divergence, manual).
    pub fn resyncs(&self) -> u64 {
        self.resyncs
    }

    /// KKT set-membership changes observed across migration steps.
    pub fn migrations(&self) -> u64 {
        self.migrations
    }

    /// Updates since the last full re-solve.
    pub fn since_resync(&self) -> usize {
        self.since_resync
    }

    /// `true` once the staleness budget is spent (callers that want to
    /// handle resync themselves — e.g. a Lifecycle full retrain —
    /// construct with `stale_budget: 0` and poll this via
    /// [`IncrementalSvdd::since_resync`]).
    pub fn is_stale(&self) -> bool {
        self.cfg.stale_budget > 0 && self.since_resync >= self.cfg.stale_budget
    }

    /// Duality gap after the most recent update/resync.
    pub fn gap(&self) -> f64 {
        self.last_gap
    }

    pub fn solver_stats(&self) -> &SolverStats {
        &self.solver
    }

    pub fn params(&self) -> &SvddParams {
        &self.params
    }

    pub fn config(&self) -> &IncrementalConfig {
        &self.cfg
    }

    /// Add one point. Costs one O(k·d) kernel column plus the
    /// migration loop; existing gradients are untouched by the append
    /// itself because the new variable starts at zero mass.
    pub fn add_point(&mut self, x: &[f64]) -> Result<()> {
        if let Some(d) = self.dim() {
            if x.len() != d {
                return Err(Error::invalid(format!(
                    "incremental add: dim {} vs active dim {d}",
                    x.len()
                )));
            }
        }
        if x.is_empty() || x.iter().any(|v| !v.is_finite()) {
            return Err(Error::invalid("incremental add: empty or non-finite point"));
        }
        let n = self.points.len();
        self.ensure_cap(n + 1);
        let nx = crate::linalg::dot(x, x);
        let mut ka = 0.0;
        for i in 0..n {
            let v = self
                .params
                .kernel
                .eval_cached(&self.points[i], self.norms[i], x, nx);
            self.gram[n * self.cap + i] = v;
            self.gram[i * self.cap + n] = v;
            ka += self.alpha[i] * v;
        }
        let d = self.params.kernel.diag_from_norm(nx);
        self.gram[n * self.cap + n] = d;
        self.points.push(x.to_vec());
        self.norms.push(nx);
        self.alpha.push(0.0);
        self.g.push(2.0 * ka - d);
        self.updates += 1;
        self.since_resync += 1;
        let steps = self.adjust()?;
        self.emit_update("add", steps);
        Ok(())
    }

    /// Remove the point at slot `i`. The last point is swapped into
    /// slot `i` (O(k) bookkeeping); use
    /// [`super::InsertionOrder`] to keep a FIFO view across swaps. The
    /// departing dual mass is handed back to the remaining variables
    /// and the migration loop restores optimality.
    pub fn remove_point(&mut self, i: usize) -> Result<()> {
        let n = self.points.len();
        if i >= n {
            return Err(Error::invalid(format!(
                "incremental remove: index {i} out of range (n={n})"
            )));
        }
        let freed = self.alpha[i];
        if freed != 0.0 {
            for k in 0..n {
                self.g[k] -= 2.0 * freed * self.gram[k * self.cap + i];
            }
        }
        let last = n - 1;
        if i != last {
            // move row `last` into row `i`, then column `last` into
            // column `i`; the row move already placed K(last,last) at
            // (i, last), so the column move lands the diagonal right.
            for k in 0..n {
                self.gram[i * self.cap + k] = self.gram[last * self.cap + k];
            }
            for k in 0..n {
                self.gram[k * self.cap + i] = self.gram[k * self.cap + last];
            }
        }
        self.points.swap_remove(i);
        self.norms.swap_remove(i);
        self.alpha.swap_remove(i);
        self.g.swap_remove(i);
        self.updates += 1;
        self.since_resync += 1;
        if self.points.is_empty() {
            self.last_gap = 0.0;
            self.emit_update("remove", 0);
            return Ok(());
        }
        if freed > 0.0 {
            self.redistribute(freed)?;
        }
        let steps = self.adjust()?;
        self.emit_update("remove", steps);
        Ok(())
    }

    /// Hand `mass` to the variables with box headroom, largest alphas
    /// (current SVs) first, index as tie-break — deterministic, and the
    /// migration loop re-optimizes the placement anyway. Total
    /// headroom always suffices: `k C = 1/f >= 1`.
    fn redistribute(&mut self, mut mass: f64) -> Result<()> {
        let n = self.points.len();
        let c = self.params.c_for(n)?;
        let mut order: Vec<usize> = (0..n).collect();
        order.sort_by(|&a, &b| {
            self.alpha[b]
                .partial_cmp(&self.alpha[a])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        for j in order {
            if mass <= 0.0 {
                break;
            }
            let room = (c - self.alpha[j]).max(0.0);
            if room <= 0.0 {
                continue;
            }
            let d = room.min(mass);
            self.bump(j, d);
            mass -= d;
        }
        Ok(())
    }

    /// Drain `mass` from the smallest positive variables first (used
    /// only for numerical sum repair; structurally the sum never
    /// overshoots 1).
    fn drain(&mut self, mut mass: f64) {
        let n = self.points.len();
        let mut order: Vec<usize> = (0..n).filter(|&j| self.alpha[j] > 0.0).collect();
        order.sort_by(|&a, &b| {
            self.alpha[a]
                .partial_cmp(&self.alpha[b])
                .unwrap_or(std::cmp::Ordering::Equal)
                .then(a.cmp(&b))
        });
        for j in order {
            if mass <= 0.0 {
                break;
            }
            let d = self.alpha[j].min(mass);
            self.bump(j, -d);
            mass -= d;
        }
    }

    /// `alpha[j] += delta` with the matching O(k) gradient update.
    fn bump(&mut self, j: usize, delta: f64) {
        self.alpha[j] += delta;
        let n = self.points.len();
        for k in 0..n {
            self.g[k] += 2.0 * delta * self.gram[k * self.cap + j];
        }
    }

    /// Restore KKT optimality after a structural change: re-clamp to
    /// the current box (C depends on k), repair the simplex sum, then
    /// run maximal-violating-pair migration steps until the gap closes.
    /// Returns the number of migration steps taken; triggers a resync
    /// when the loop diverges or the staleness budget is spent.
    fn adjust(&mut self) -> Result<usize> {
        let n = self.points.len();
        let c = self.params.c_for(n)?;
        let eps = self.params.smo.sv_eps;
        for j in 0..n {
            if self.alpha[j] > c {
                let d = c - self.alpha[j];
                self.bump(j, d);
            }
        }
        let s: f64 = self.alpha.iter().sum();
        if s < 1.0 - 1e-12 {
            self.redistribute(1.0 - s)?;
        } else if s > 1.0 + 1e-12 {
            self.drain(s - 1.0);
        }
        let tol = self.params.smo.tol;
        let cap_steps = if self.cfg.adjust_iters > 0 {
            self.cfg.adjust_iters
        } else {
            64 * n.max(8)
        };
        let mut steps = 0usize;
        loop {
            let mut up = usize::MAX;
            let mut g_up = f64::INFINITY;
            let mut dn = usize::MAX;
            let mut g_dn = f64::NEG_INFINITY;
            for k in 0..n {
                if self.alpha[k] < c - eps && self.g[k] < g_up {
                    g_up = self.g[k];
                    up = k;
                }
                if self.alpha[k] > eps && self.g[k] > g_dn {
                    g_dn = self.g[k];
                    dn = k;
                }
            }
            if up == usize::MAX || dn == usize::MAX || up == dn {
                self.last_gap = 0.0;
                break;
            }
            let gap = g_dn - g_up;
            self.last_gap = gap;
            if gap <= tol || steps >= cap_steps {
                break;
            }
            let kij = self.gram[up * self.cap + dn];
            let eta = (2.0
                * (self.gram[up * self.cap + up] + self.gram[dn * self.cap + dn] - 2.0 * kij))
                .max(1e-12);
            let t = (gap / eta)
                .min(c - self.alpha[up])
                .min(self.alpha[dn]);
            if t <= 0.0 {
                break;
            }
            let was_up = classify(self.alpha[up], c, eps);
            let was_dn = classify(self.alpha[dn], c, eps);
            self.alpha[up] += t;
            self.alpha[dn] -= t;
            for k in 0..n {
                self.g[k] +=
                    2.0 * t * (self.gram[k * self.cap + up] - self.gram[k * self.cap + dn]);
            }
            if classify(self.alpha[up], c, eps) != was_up {
                self.migrations += 1;
            }
            if classify(self.alpha[dn], c, eps) != was_dn {
                self.migrations += 1;
            }
            steps += 1;
        }
        if self.last_gap > self.cfg.divergence_tol && steps >= cap_steps {
            self.solve_active(Some("carry"), "divergence")?;
        } else if self.cfg.stale_budget > 0 && self.since_resync >= self.cfg.stale_budget {
            self.solve_active(Some("carry"), "stale")?;
        }
        Ok(steps)
    }

    /// Force a full warm-started re-solve of the active set now.
    pub fn resync(&mut self) -> Result<()> {
        self.solve_active(Some("carry"), "manual")
    }

    /// Full SMO solve over the active set's Gram. `init` of `Some`
    /// warm-starts from the maintained alpha ("carry"); `None` is a
    /// cold seed solve. Re-derives the gradient exactly.
    fn solve_active(&mut self, init: Option<&'static str>, reason: &'static str) -> Result<()> {
        let n = self.points.len();
        if n == 0 {
            return Ok(());
        }
        let c = self.params.c_for(n)?;
        let mut dense = vec![0.0; n * n];
        for i in 0..n {
            dense[i * n..(i + 1) * n]
                .copy_from_slice(&self.gram[i * self.cap..i * self.cap + n]);
        }
        let mut kp = DenseKernel::new(dense, n)?;
        let warm = init.map(|_| self.alpha.clone());
        let sol = smo::solve_with_init(&mut kp, c, &self.params.smo, warm.as_deref())?;
        self.solver.absorb(&SolverStats::from_solution(&sol, 0, 0));
        self.alpha = sol.alpha;
        self.g = sol.gradient;
        self.last_gap = sol.gap;
        self.resyncs += 1;
        self.since_resync = 0;
        if crate::obs::enabled() {
            crate::obs::emit(
                "incremental.resync",
                vec![
                    ("reason", Value::Str(reason.to_string())),
                    ("points", Value::U64(n as u64)),
                    ("iterations", Value::U64(sol.iterations as u64)),
                ],
            );
        }
        Ok(())
    }

    fn emit_update(&self, op: &'static str, steps: usize) {
        if crate::obs::enabled() {
            crate::obs::emit(
                "incremental.update",
                vec![
                    ("op", Value::Str(op.to_string())),
                    ("points", Value::U64(self.points.len() as u64)),
                    ("steps", Value::U64(steps as u64)),
                    ("gap", Value::F64(self.last_gap)),
                ],
            );
        }
    }

    /// `a' K a` at the current solution (via the gradient identity
    /// `(K a)_i = (g_i + K_ii) / 2`, same as the batch solver).
    pub fn quad(&self) -> f64 {
        let n = self.points.len();
        (0..n)
            .map(|i| self.alpha[i] * (self.g[i] + self.gram[i * self.cap + i]) * 0.5)
            .sum()
    }

    /// Squared threshold radius: mean of `quad - g_k` over boundary
    /// SVs, falling back to all SVs — the batch solver's estimator on
    /// the maintained state.
    pub fn r2(&self) -> f64 {
        let n = self.points.len();
        if n == 0 {
            return 0.0;
        }
        let c = match self.params.c_for(n) {
            Ok(c) => c,
            Err(_) => return 0.0,
        };
        let eps = self.params.smo.sv_eps;
        let quad = self.quad();
        let mut sum = 0.0;
        let mut cnt = 0usize;
        for k in 0..n {
            if self.alpha[k] > eps && self.alpha[k] < c - eps {
                sum += quad - self.g[k];
                cnt += 1;
            }
        }
        if cnt == 0 {
            for k in 0..n {
                if self.alpha[k] > eps {
                    sum += quad - self.g[k];
                    cnt += 1;
                }
            }
        }
        if cnt > 0 {
            (sum / cnt as f64).max(0.0)
        } else {
            0.0
        }
    }

    /// KKT set sizes `(interior, boundary, outlier)` of the active set.
    pub fn set_sizes(&self) -> (usize, usize, usize) {
        let n = self.points.len();
        let c = match self.params.c_for(n) {
            Ok(c) => c,
            Err(_) => return (0, 0, 0),
        };
        let eps = self.params.smo.sv_eps;
        let mut sizes = (0usize, 0usize, 0usize);
        for k in 0..n {
            match classify(self.alpha[k], c, eps) {
                KktSet::Interior => sizes.0 += 1,
                KktSet::Boundary => sizes.1 += 1,
                KktSet::Outlier => sizes.2 += 1,
            }
        }
        sizes
    }

    /// Materialize the current solution as a scoring model, with the
    /// batch trainer's finalize recipe: keep `alpha > sv_eps`,
    /// renormalize to sum exactly 1, recompute `W = a' K a` over the
    /// retained SVs from the maintained Gram.
    pub fn model(&self) -> Result<SvddModel> {
        let n = self.points.len();
        if n == 0 {
            return Err(Error::invalid("incremental model: empty active set"));
        }
        let eps = self.params.smo.sv_eps;
        let idx: Vec<usize> = (0..n).filter(|&i| self.alpha[i] > eps).collect();
        if idx.is_empty() {
            return Err(Error::Solver("no support vectors extracted".into()));
        }
        let rows: Vec<Vec<f64>> = idx.iter().map(|&i| self.points[i].clone()).collect();
        let sv = Matrix::from_rows(&rows)?;
        let mut alpha: Vec<f64> = idx.iter().map(|&i| self.alpha[i]).collect();
        let total: f64 = alpha.iter().sum();
        for a in &mut alpha {
            *a /= total;
        }
        let mut w = 0.0;
        for (ii, &i) in idx.iter().enumerate() {
            for (jj, &j) in idx.iter().enumerate() {
                w += alpha[ii] * alpha[jj] * self.gram[i * self.cap + j];
            }
        }
        SvddModel::new(sv, alpha, self.params.kernel, self.r2(), w)
    }

    /// Grow the stride-`cap` Gram allocation (geometric, so long
    /// streams amortize to O(k) per add).
    fn ensure_cap(&mut self, need: usize) {
        if need <= self.cap {
            return;
        }
        let ncap = need.next_power_of_two().max(64);
        let mut ng = vec![0.0; ncap * ncap];
        let n = self.points.len();
        for i in 0..n {
            ng[i * ncap..i * ncap + n].copy_from_slice(&self.gram[i * self.cap..i * self.cap + n]);
        }
        self.gram = ng;
        self.cap = ncap;
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::svdd::trainer::train;
    use crate::util::rng::Xoshiro256;

    fn ring(n: usize, seed: u64) -> Matrix {
        let mut rng = Xoshiro256::new(seed);
        let rows: Vec<Vec<f64>> = (0..n)
            .map(|_| {
                let th = rng.range(0.0, std::f64::consts::TAU);
                let r = rng.range(0.8, 1.2);
                vec![r * th.cos(), r * th.sin()]
            })
            .collect();
        Matrix::from_rows(&rows).unwrap()
    }

    fn params() -> SvddParams {
        SvddParams::gaussian(0.6, 0.05)
    }

    fn no_resync() -> IncrementalConfig {
        IncrementalConfig { stale_budget: 0, ..Default::default() }
    }

    #[test]
    fn seed_matches_batch_solve() {
        let data = ring(120, 1);
        let inc = IncrementalSvdd::with_data(params(), no_resync(), &data).unwrap();
        let batch = train(&data, &params()).unwrap();
        let rel = (inc.r2() - batch.r2()).abs() / batch.r2();
        assert!(rel < 1e-6, "seed r2 {} vs batch {}", inc.r2(), batch.r2());
        assert_eq!(inc.resyncs(), 1);
    }

    #[test]
    fn sequential_adds_match_batch_within_tolerance() {
        // Property: n sequential add_point calls agree with one batch
        // solve on the same rows within the documented 1% tolerance.
        let data = ring(150, 2);
        let mut inc = IncrementalSvdd::new(params(), no_resync());
        for i in 0..data.rows() {
            inc.add_point(data.row(i)).unwrap();
        }
        assert_eq!(inc.updates(), 150);
        let batch = train(&data, &params()).unwrap();
        let rel = (inc.r2() - batch.r2()).abs() / batch.r2();
        assert!(rel < 0.01, "incremental r2 {} vs batch {} (rel {rel})", inc.r2(), batch.r2());
        assert!(inc.gap() <= inc.params().smo.tol * 10.0, "gap {}", inc.gap());
    }

    #[test]
    fn add_then_remove_roundtrip_restores_model() {
        // Property: adding a point and removing it again returns the
        // solution to the original optimum within tolerance.
        let data = ring(100, 3);
        let mut inc = IncrementalSvdd::with_data(params(), no_resync(), &data).unwrap();
        let before = inc.model().unwrap();
        inc.add_point(&[3.0, -3.0]).unwrap();
        let slot = inc.len() - 1;
        inc.remove_point(slot).unwrap();
        let after = inc.model().unwrap();
        let rel = (after.r2() - before.r2()).abs() / before.r2();
        assert!(rel < 1e-4, "roundtrip drifted: {} -> {}", before.r2(), after.r2());
        let dsv = (after.num_sv() as i64 - before.num_sv() as i64).abs();
        assert!(dsv <= 2, "SV count moved {} -> {}", before.num_sv(), after.num_sv());
        assert_eq!(inc.len(), 100);
    }

    #[test]
    fn remove_point_swaps_last_into_slot() {
        let data = ring(10, 4);
        let mut inc = IncrementalSvdd::with_data(params(), no_resync(), &data).unwrap();
        let last_row = inc.points[9].clone();
        inc.remove_point(3).unwrap();
        assert_eq!(inc.len(), 9);
        assert_eq!(inc.points[3], last_row);
        // gram row 3 must describe the moved point: diag is K(x,x)=1
        let k35 = inc.params.kernel.eval_cached(
            &inc.points[3],
            inc.norms[3],
            &inc.points[5],
            inc.norms[5],
        );
        assert!((inc.gram[3 * inc.cap + 5] - k35).abs() < 1e-15);
        assert!((inc.gram[5 * inc.cap + 3] - k35).abs() < 1e-15);
        assert!((inc.gram[3 * inc.cap + 3] - 1.0).abs() < 1e-15);
    }

    #[test]
    fn maintained_gradient_stays_exact_under_updates() {
        let data = ring(60, 5);
        let mut inc = IncrementalSvdd::with_data(params(), no_resync(), &data).unwrap();
        let mut rng = Xoshiro256::new(9);
        for _ in 0..30 {
            let th = rng.range(0.0, std::f64::consts::TAU);
            inc.add_point(&[th.cos(), th.sin()]).unwrap();
            inc.remove_point(rng.index(inc.len())).unwrap();
        }
        // recompute g from scratch and compare with the maintained one
        let n = inc.len();
        for k in 0..n {
            let ka: f64 = (0..n).map(|j| inc.alpha[j] * inc.gram[k * inc.cap + j]).sum();
            let fresh = 2.0 * ka - inc.gram[k * inc.cap + k];
            assert!(
                (fresh - inc.g[k]).abs() < 1e-9,
                "gradient drifted at {k}: {} vs {fresh}",
                inc.g[k]
            );
        }
        let s: f64 = inc.alpha.iter().sum();
        assert!((s - 1.0).abs() < 1e-9, "sum(alpha) = {s}");
    }

    #[test]
    fn staleness_budget_forces_resyncs() {
        let data = ring(50, 6);
        let cfg = IncrementalConfig { stale_budget: 10, ..Default::default() };
        let mut inc = IncrementalSvdd::with_data(params(), cfg, &data).unwrap();
        let mut rng = Xoshiro256::new(10);
        for _ in 0..25 {
            let th = rng.range(0.0, std::f64::consts::TAU);
            inc.add_point(&[1.1 * th.cos(), 1.1 * th.sin()]).unwrap();
        }
        // seed + two budget-triggered resyncs over 25 updates
        assert!(inc.resyncs() >= 3, "resyncs = {}", inc.resyncs());
        assert!(inc.since_resync() < 10);
        assert!(!inc.is_stale());
    }

    #[test]
    fn empty_and_single_point_edges() {
        let mut inc = IncrementalSvdd::new(params(), no_resync());
        assert!(inc.is_empty());
        assert!(inc.model().is_err());
        inc.add_point(&[0.5, 0.5]).unwrap();
        let m = inc.model().unwrap();
        assert_eq!(m.num_sv(), 1);
        assert!(m.dist2(&[0.5, 0.5]).abs() < 1e-12);
        inc.remove_point(0).unwrap();
        assert!(inc.is_empty());
        assert!(inc.remove_point(0).is_err());
    }

    #[test]
    fn dimension_mismatch_rejected() {
        let mut inc = IncrementalSvdd::new(params(), no_resync());
        inc.add_point(&[0.0, 0.0]).unwrap();
        assert!(inc.add_point(&[1.0]).is_err());
        assert!(inc.add_point(&[f64::NAN, 0.0]).is_err());
    }

    #[test]
    fn set_sizes_partition_the_active_set() {
        let data = ring(80, 7);
        let inc = IncrementalSvdd::with_data(params(), no_resync(), &data).unwrap();
        let (int, bnd, out) = inc.set_sizes();
        assert_eq!(int + bnd + out, 80);
        assert!(bnd > 0, "no boundary SVs");
    }
}
