#!/usr/bin/env python3
"""Perf-regression gate for the CI bench-smoke job.

Compares a bench's BENCH_*.json output against a committed baseline and
fails (exit 1) when any gated metric regresses beyond tolerance.

Checks (all optional, combined):
  --higher-is-better k1,k2  current[k] >= baseline[k] * (1 - max_regression);
                            reported as SKIP when the current run used
                            fewer threads than the baseline capture
                            (current["threads_mt"] < baseline["threads_mt"])
                            — a weaker runner's absolute throughput is not
                            comparable to a multi-thread baseline
  --max-regression 0.20     tolerated fractional drop for the above
  --min key=value           current[key] >= value (absolute floor,
                            machine-independent — e.g. a speedup ratio)
  --min-mt key=value        like --min, but skipped (reported as SKIP)
                            when current["threads_mt"] <= 1 — a
                            single-core machine cannot demonstrate a
                            parallel speedup, and the bench's thread
                            ladder degenerates to [1] there
  --require-true k1,k2      current[k] must be boolean true (correctness
                            flags the bench computes, e.g. bit-identity)
  --forbid-scalar-isa       fail when the bench JSON reports
                            isa == "scalar" on an x86_64 runner (the
                            SIMD dispatch silently fell back), or when
                            the isa/arch provenance keys are missing
                            entirely; reported as SKIP on non-x86_64
                            arches (their best arm is their own concern)

Baselines live in ci/baselines/. To re-baseline after an intentional
perf change, copy the bench JSON from a green run's artifacts over the
baseline file and commit it alongside the change that justifies it.

Stdlib only; runs on any python3.
"""

import argparse
import json
import sys


def load(path):
    with open(path) as fh:
        return json.load(fh)


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--baseline", required=True)
    ap.add_argument("--current", required=True)
    ap.add_argument("--higher-is-better", default="",
                    help="comma-separated metric keys gated vs the baseline")
    ap.add_argument("--max-regression", type=float, default=0.20,
                    help="tolerated fractional drop vs baseline (default 0.20)")
    ap.add_argument("--min", action="append", default=[],
                    metavar="KEY=VALUE",
                    help="absolute floor for a metric (repeatable)")
    ap.add_argument("--min-mt", action="append", default=[],
                    metavar="KEY=VALUE",
                    help="absolute floor enforced only when "
                         "current['threads_mt'] > 1 (repeatable)")
    ap.add_argument("--require-true", default="",
                    help="comma-separated keys that must be true")
    ap.add_argument("--forbid-scalar-isa", action="store_true",
                    help="fail if the bench reports isa == 'scalar' on "
                         "x86_64, or carries no isa/arch provenance")
    args = ap.parse_args()

    baseline = load(args.baseline)
    current = load(args.current)
    failures = []

    def report(ok, line):
        print(("PASS  " if ok else "FAIL  ") + line)
        if not ok:
            failures.append(line)

    cur_threads = float(current.get("threads_mt", 1))
    base_threads = float(baseline.get("threads_mt", 1))
    comparable = cur_threads >= base_threads
    for key in filter(None, args.higher_is_better.split(",")):
        if not comparable:
            print(f"SKIP  {key}: run used {cur_threads:.0f} thread(s) vs "
                  f"baseline's {base_threads:.0f} — throughput not comparable")
            continue
        if key not in baseline:
            report(False, f"{key}: missing from baseline {args.baseline}")
            continue
        if key not in current:
            report(False, f"{key}: missing from current {args.current}")
            continue
        base, cur = float(baseline[key]), float(current[key])
        floor = base * (1.0 - args.max_regression)
        report(cur >= floor,
               f"{key}: current {cur:.4g} vs baseline {base:.4g} "
               f"(floor {floor:.4g}, -{args.max_regression:.0%} allowed)")

    multi_threaded = float(current.get("threads_mt", 0)) > 1
    for spec, mt_only in [(s, False) for s in args.min] + \
                         [(s, True) for s in args.min_mt]:
        key, _, value = spec.partition("=")
        if mt_only and not multi_threaded:
            print(f"SKIP  {key}: threads_mt <= 1, speedup floor not applicable")
            continue
        if key not in current:
            report(False, f"{key}: missing from current {args.current}")
            continue
        cur, floor = float(current[key]), float(value)
        report(cur >= floor, f"{key}: current {cur:.4g} vs absolute floor {floor:.4g}")

    for key in filter(None, args.require_true.split(",")):
        val = current.get(key)
        report(val is True, f"{key}: expected true, got {val!r}")

    if args.forbid_scalar_isa:
        arch, isa = current.get("arch"), current.get("isa")
        if arch is None or isa is None:
            report(False, f"isa: provenance missing from {args.current} "
                          f"(arch={arch!r}, isa={isa!r}; the bench must "
                          "stamp bench::isa_provenance())")
        elif arch != "x86_64":
            print(f"SKIP  isa: arch '{arch}' is not x86_64 "
                  f"(dispatched arm '{isa}')")
        else:
            report(isa != "scalar",
                   f"isa: dispatched arm '{isa}' on x86_64 — SIMD dispatch "
                   "must engage on CI runners (AVX2 is universal there); "
                   "'scalar' means detection or dispatch silently broke")

    if failures:
        print(f"\nperf gate FAILED ({len(failures)} check(s)); "
              "if this regression is intentional, re-baseline ci/baselines/ "
              "(see ci/check_perf.py docstring)")
        return 1
    print("\nperf gate passed")
    return 0


if __name__ == "__main__":
    sys.exit(main())
