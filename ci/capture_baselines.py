#!/usr/bin/env python3
"""Turn a green bench run's BENCH_*.json artifacts into ready-to-commit
baseline files for ci/baselines/.

The bench-smoke job runs this after its gates pass and uploads the
output as the `baseline-candidates` artifact; re-baselining is then:
download the artifact, copy the wanted file(s) over ci/baselines/, and
commit with the change that justifies the new numbers. Run it locally
the same way against `rust/results/` after `cargo bench`.

Each candidate is the bench JSON verbatim plus a `_captured` stanza
recording where the numbers came from (runner, bench scale, dispatched
ISA arm, arch, capture time) — provenance the baseline README requires
so a committed floor is auditable back to real hardware.

Stdlib only; runs on any python3.
"""

import argparse
import datetime
import json
import os
import sys

# The gates that compare absolute throughput against a committed
# baseline (the others gate on same-machine ratios/booleans only and
# never need a capture).
DEFAULT_BENCHES = ["perf_kernel", "perf_parallel", "perf_serving"]


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--results", default="rust/results",
                    help="directory holding BENCH_*.json from a bench run")
    ap.add_argument("--out", default="baseline-candidates",
                    help="directory to write candidate baselines into")
    ap.add_argument("--runner", default=os.environ.get("RUNNER_NAME", "local"),
                    help="runner label for the provenance stanza")
    ap.add_argument("--scale",
                    default=os.environ.get("FASTSVDD_BENCH_SCALE", "1.0"),
                    help="FASTSVDD_BENCH_SCALE the run used")
    ap.add_argument("--benches", default=",".join(DEFAULT_BENCHES),
                    help="comma-separated bench names to capture")
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    captured = []
    for name in filter(None, args.benches.split(",")):
        src = os.path.join(args.results, f"BENCH_{name}.json")
        if not os.path.exists(src):
            print(f"skip  {name}: {src} not found")
            continue
        with open(src) as fh:
            data = json.load(fh)
        data["_captured"] = {
            "source": f"BENCH_{name}.json from a bench run",
            "runner": args.runner,
            "bench_scale": args.scale,
            "isa": data.get("isa", "unknown"),
            "arch": data.get("arch", "unknown"),
            "utc": datetime.datetime.now(datetime.timezone.utc)
                   .strftime("%Y-%m-%dT%H:%M:%SZ"),
        }
        dst = os.path.join(args.out, f"BENCH_{name}.json")
        with open(dst, "w") as fh:
            json.dump(data, fh, indent=2, sort_keys=True)
            fh.write("\n")
        captured.append(dst)
        print(f"wrote {dst} (isa={data['_captured']['isa']}, "
              f"scale={args.scale})")

    if not captured:
        print("no bench JSON captured — did the bench run emit results?")
        return 1
    print(f"\n{len(captured)} baseline candidate(s) ready; to re-baseline, "
          "copy over ci/baselines/ and commit (see ci/baselines/README.md)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
