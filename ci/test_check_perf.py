#!/usr/bin/env python3
"""Self-test for ci/check_perf.py — the perf gate the bench-smoke job
runs. Exercises every check class with synthetic bench JSON, including
the demonstration the ISSUE asks for: a BENCH file reporting
`isa: "scalar"` on an x86_64 runner must FAIL the gate when
`--forbid-scalar-isa` is on.

Stdlib only; run directly (`python3 ci/test_check_perf.py`) or let the
CI bench-smoke job run it before the real gates.
"""

import json
import os
import subprocess
import sys
import tempfile

HERE = os.path.dirname(os.path.abspath(__file__))
GATE = os.path.join(HERE, "check_perf.py")


def run_gate(baseline, current, *extra_args):
    """Write both JSONs to temp files and run the gate; return
    (exit_code, stdout)."""
    with tempfile.TemporaryDirectory() as td:
        bp = os.path.join(td, "baseline.json")
        cp = os.path.join(td, "current.json")
        with open(bp, "w") as fh:
            json.dump(baseline, fh)
        with open(cp, "w") as fh:
            json.dump(current, fh)
        proc = subprocess.run(
            [sys.executable, GATE, "--baseline", bp, "--current", cp]
            + list(extra_args),
            capture_output=True, text=True)
        return proc.returncode, proc.stdout + proc.stderr


PASSED = 0


def check(name, cond, detail=""):
    global PASSED
    if not cond:
        print(f"FAIL  {name}  {detail}")
        sys.exit(1)
    PASSED += 1
    print(f"ok    {name}")


def main():
    base = {"threads_mt": 4, "tp": 100.0}

    # ---- higher-is-better vs baseline ----
    code, out = run_gate(base, {"threads_mt": 4, "tp": 95.0},
                         "--higher-is-better", "tp")
    check("within tolerance passes", code == 0, out)

    code, out = run_gate(base, {"threads_mt": 4, "tp": 60.0},
                         "--higher-is-better", "tp")
    check("20%+ regression fails", code == 1, out)

    code, out = run_gate(base, {"threads_mt": 2, "tp": 10.0},
                         "--higher-is-better", "tp")
    check("weaker runner skips throughput comparison",
          code == 0 and "SKIP" in out, out)

    # ---- absolute floors ----
    code, out = run_gate(base, {"threads_mt": 4, "ratio": 2.5},
                         "--min", "ratio=2.0")
    check("ratio above floor passes", code == 0, out)

    code, out = run_gate(base, {"threads_mt": 4, "ratio": 1.5},
                         "--min", "ratio=2.0")
    check("ratio below floor fails", code == 1, out)

    code, out = run_gate(base, {"threads_mt": 1, "speedup": 0.9},
                         "--min-mt", "speedup=1.3")
    check("single-core skips --min-mt floors",
          code == 0 and "SKIP" in out, out)

    # ---- correctness booleans ----
    code, out = run_gate(base, {"threads_mt": 4, "bit_identical": True},
                         "--require-true", "bit_identical")
    check("true flag passes", code == 0, out)

    code, out = run_gate(base, {"threads_mt": 4, "bit_identical": False},
                         "--require-true", "bit_identical")
    check("false flag fails", code == 1, out)

    code, out = run_gate(base, {"threads_mt": 4},
                         "--require-true", "bit_identical")
    check("missing flag fails", code == 1, out)

    # ---- --forbid-scalar-isa (the dispatch-engaged tripwire) ----
    simd = {"threads_mt": 4, "isa": "avx2", "arch": "x86_64"}
    code, out = run_gate(base, simd, "--forbid-scalar-isa")
    check("avx2 on x86_64 passes", code == 0, out)

    fma = dict(simd, isa="fma")
    code, out = run_gate(base, fma, "--forbid-scalar-isa")
    check("fma on x86_64 passes", code == 0, out)

    # THE demonstration: forced-scalar run on an x86_64 runner trips the
    # gate (what CI would see if dispatch silently fell back, or if
    # FASTSVDD_ISA=scalar leaked into the bench job)
    scalar = dict(simd, isa="scalar")
    code, out = run_gate(base, scalar, "--forbid-scalar-isa")
    check("FORCED-SCALAR ON x86_64 FAILS THE GATE",
          code == 1 and "scalar" in out, out)

    code, out = run_gate(base, {"threads_mt": 4}, "--forbid-scalar-isa")
    check("missing isa/arch provenance fails", code == 1, out)

    neon = {"threads_mt": 4, "isa": "neon", "arch": "aarch64"}
    code, out = run_gate(base, neon, "--forbid-scalar-isa")
    check("non-x86_64 arch skips the scalar check",
          code == 0 and "SKIP" in out, out)

    arm_scalar = {"threads_mt": 4, "isa": "scalar", "arch": "aarch64"}
    code, out = run_gate(base, arm_scalar, "--forbid-scalar-isa")
    check("scalar on aarch64 is not an error (skipped)",
          code == 0 and "SKIP" in out, out)

    # ---- without the flag, scalar isa is not checked at all ----
    code, out = run_gate(base, scalar)
    check("scalar isa passes when the flag is off", code == 0, out)

    # ---- combined: one failing check fails the whole gate ----
    cur = {"threads_mt": 4, "tp": 99.0, "ratio": 0.5,
           "isa": "avx2", "arch": "x86_64"}
    code, out = run_gate(base, cur, "--higher-is-better", "tp",
                         "--min", "ratio=2.0", "--forbid-scalar-isa")
    check("one failing check fails a combined run", code == 1, out)

    print(f"\nall {PASSED} gate self-tests passed")


if __name__ == "__main__":
    main()
