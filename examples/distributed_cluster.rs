//! Distributed training demo (paper section III-1, Fig 2): spin up TCP
//! workers, shard the paper's largest workload (Two-Donut) across them,
//! union the per-worker master SV sets on the controller, and compare
//! against the in-process cluster and the plain sampling method.
//!
//! Run: `cargo run --release --example distributed_cluster [-- rows]`

use fastsvdd::data::{donut::TwoDonut, Generator};
use fastsvdd::distributed::tcp::{train_tcp_cluster, WorkerServer};
use fastsvdd::distributed::{train_local_cluster, DistributedConfig};
use fastsvdd::sampling::{SamplingConfig, SamplingTrainer};
use fastsvdd::svdd::SvddParams;
use fastsvdd::util::timer::{fmt_duration, Stopwatch};

fn main() -> fastsvdd::Result<()> {
    let rows: usize = std::env::args()
        .nth(1)
        .and_then(|a| a.parse().ok())
        .unwrap_or(200_000);
    let data = TwoDonut::default().generate(rows, 42);
    let params = SvddParams::gaussian(0.5, 0.001);
    let cfg = DistributedConfig {
        workers: 4,
        sampling: SamplingConfig { sample_size: 11, ..Default::default() },
        seed: 7,
        ..Default::default()
    };

    // ---- real TCP workers on loopback ----
    let mut workers: Vec<WorkerServer> = (0..4)
        .map(|_| WorkerServer::spawn("127.0.0.1:0").expect("bind worker"))
        .collect();
    let addrs: Vec<_> = workers.iter().map(|w| w.addr()).collect();
    println!("spawned {} TCP workers: {:?}", addrs.len(), addrs);

    let sw = Stopwatch::start();
    let tcp = train_tcp_cluster(&data, &params, &cfg, &addrs)?;
    let t_tcp = sw.elapsed_secs();
    for r in &tcp.reports {
        println!(
            "  worker {}: shard={} rows -> {} SVs in {} iterations (converged={})",
            r.worker, r.shard_rows, r.sv_count, r.iterations, r.converged
        );
    }
    println!(
        "TCP cluster: R^2={:.4} #SV={} union={} rows, total {}",
        tcp.model.r2(),
        tcp.model.num_sv(),
        tcp.union_rows,
        fmt_duration(t_tcp)
    );

    // ---- in-process cluster (same seeds -> identical result) ----
    let sw = Stopwatch::start();
    let local = train_local_cluster(&data, &params, &cfg)?;
    println!(
        "local cluster: R^2={:.4} #SV={} in {} (matches TCP: {})",
        local.model.r2(),
        local.model.num_sv(),
        fmt_duration(sw.elapsed_secs()),
        (local.model.r2() - tcp.model.r2()).abs() < 1e-12
    );

    // ---- single-process sampling baseline ----
    let sw = Stopwatch::start();
    let single = SamplingTrainer::new(params, cfg.sampling).train(&data, 7)?;
    println!(
        "single sampling: R^2={:.4} #SV={} in {}",
        single.model.r2(),
        single.model.num_sv(),
        fmt_duration(sw.elapsed_secs())
    );

    for w in &mut workers {
        w.stop();
    }
    Ok(())
}
