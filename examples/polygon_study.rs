//! Mini version of the paper's simulation study (section VI) on one
//! random polygon: train full vs sampling across the bandwidth sweep,
//! report the F1 ratio, and write the inside/outside grid maps as PGM
//! images (plus the polygon + training points as CSV).
//!
//! Run: `cargo run --release --example polygon_study [-- vertices]`

use fastsvdd::baselines::train_full;
use fastsvdd::data::grid::{agreement, Grid};
use fastsvdd::data::polygon::Polygon;
use fastsvdd::sampling::{SamplingConfig, SamplingTrainer};
use fastsvdd::scoring::{F1Score, Scorer};
use fastsvdd::svdd::SvddParams;

fn main() -> fastsvdd::Result<()> {
    let k: usize = std::env::args().nth(1).and_then(|a| a.parse().ok()).unwrap_or(12);
    let poly = Polygon::random(k, 3.0, 5.0, 7);
    println!("random polygon: {k} vertices, area {:.2}", poly.area());

    let train = poly.sample_interior(600, 11);
    let ((x0, y0), (x1, y1)) = poly.bbox();
    let grid = Grid { nx: 200, ny: 200, x0, x1, y0, y1 };
    let truth = grid.labels_from(|x, y| poly.contains(x, y));
    let pts = grid.points();

    println!(
        "{:>6} {:>9} {:>12} {:>8} {:>10}",
        "s", "F1_full", "F1_sampling", "ratio", "agreement"
    );
    let mut best = (0.0f64, 0.0f64, 0.0f64);
    for s in [1.0, 1.44, 1.88, 2.33, 2.77, 3.22, 3.66, 4.11, 4.55, 5.0] {
        let params = SvddParams::gaussian(s, 0.01);
        let full = train_full(&train, &params)?.model;
        let cfg = SamplingConfig { sample_size: 5, ..Default::default() };
        let samp = SamplingTrainer::new(params, cfg).train(&train, 3)?.model;
        let inside_full = Scorer::native(&full).inside_batch(&pts)?;
        let inside_samp = Scorer::native(&samp).inside_batch(&pts)?;
        let f1f = F1Score::compute(&truth, &inside_full).f1;
        let f1s = F1Score::compute(&truth, &inside_samp).f1;
        let agr = agreement(&inside_full, &inside_samp);
        println!("{s:>6.2} {f1f:>9.4} {f1s:>12.4} {:>8.4} {:>9.1}%", f1s / f1f, agr * 100.0);
        if f1f > best.0 {
            best = (f1f, f1s, s);
            // write the best-s maps
            grid.write_pgm(&truth, std::path::Path::new("polygon_truth.pgm"))?;
            grid.write_pgm(&inside_full, std::path::Path::new("polygon_full.pgm"))?;
            grid.write_pgm(&inside_samp, std::path::Path::new("polygon_sampling.pgm"))?;
        }
    }
    println!(
        "\nbest s = {}: F1_full = {:.4}, F1_sampling = {:.4}, ratio = {:.4}",
        best.2,
        best.0,
        best.1,
        best.1 / best.0
    );
    println!("maps written: polygon_truth.pgm, polygon_full.pgm, polygon_sampling.pgm");
    Ok(())
}
