//! Quickstart: train an SVDD description of the Banana data with the
//! paper's sampling method, compare it against the full method, and
//! score some points.
//!
//! Run: `cargo run --release --example quickstart`

use fastsvdd::baselines::train_full;
use fastsvdd::data::{banana::Banana, Generator};
use fastsvdd::sampling::{SamplingConfig, SamplingTrainer};
use fastsvdd::scoring::Scorer;
use fastsvdd::svdd::SvddParams;
use fastsvdd::util::timer::{fmt_duration, Stopwatch};

fn main() -> fastsvdd::Result<()> {
    // 1. data: 11,016 banana-shaped observations (paper Table I)
    let data = Banana::default().generate(11_016, 42);

    // 2. parameters: Gaussian bandwidth + expected outlier fraction
    let params = SvddParams::gaussian(0.35, 0.001);

    // 3. the paper's Algorithm 1, sample size 6
    let cfg = SamplingConfig { sample_size: 6, ..Default::default() };
    let sw = Stopwatch::start();
    let sampled = SamplingTrainer::new(params, cfg).train(&data, 7)?;
    let t_sampling = sw.elapsed_secs();

    // 4. the full-SVDD baseline for comparison
    let full = train_full(&data, &params)?;

    println!("== sampling method (Algorithm 1) ==");
    println!(
        "  R^2 = {:.4}   #SV = {}   iterations = {}   time = {}",
        sampled.model.r2(),
        sampled.model.num_sv(),
        sampled.iterations,
        fmt_duration(t_sampling),
    );
    println!(
        "  rows touched: {} of {} ({:.2}%)",
        sampled.rows_touched,
        data.rows(),
        100.0 * sampled.rows_touched as f64 / data.rows() as f64
    );
    println!("== full SVDD method ==");
    println!(
        "  R^2 = {:.4}   #SV = {}   time = {}",
        full.model.r2(),
        full.model.num_sv(),
        fmt_duration(full.seconds),
    );
    println!(
        "  speedup = {:.1}x, R^2 ratio = {:.4}",
        full.seconds / t_sampling,
        sampled.model.r2() / full.model.r2()
    );

    // 5. score new observations
    let scorer = Scorer::native(&sampled.model);
    let probes = [
        ([1.0, 0.0], "on the banana"),
        ([0.0, 0.0], "in the hole"),
        ([3.0, 3.0], "far away"),
    ];
    println!("== scoring ==");
    for (p, label) in probes {
        let d2 = sampled.model.dist2(&p);
        println!(
            "  {label:>14} {p:?}: dist2 = {d2:.4} -> {}",
            if d2 > sampled.model.r2() { "OUTLIER" } else { "inside" }
        );
    }
    let _ = scorer; // scorer demonstrated above via model; batch API below
    let grid_points = Banana::default().generate(1000, 1);
    let outliers = Scorer::native(&sampled.model)
        .label_batch(&grid_points)?
        .iter()
        .filter(|&&o| o)
        .count();
    println!("  batch: {outliers}/1000 fresh banana points flagged (expect ~0)");
    Ok(())
}
