//! Unified training engine: every method through one facade.
//!
//! Trains the same banana data set with every registered method —
//! full, sampling, distributed, Luo, Kim, streaming-snapshot, exact
//! incremental (online add/remove), boundary-preserving reduction —
//! via `Engine::from_config`, then prints one comparison table built
//! from the uniform `TrainReport` fields. No per-method code anywhere:
//! adding a trainer to `engine::trainer_for` would add a row here
//! without touching this file (the two online-learning methods did
//! exactly that).
//!
//! Run with: `cargo run --release --example unified_training`

use fastsvdd::config::{Method, RunConfig};
use fastsvdd::data::{banana::Banana, Generator};
use fastsvdd::engine::Engine;
use fastsvdd::util::tables::{f, i, Table};

fn main() {
    let rows = 6000;
    let base = RunConfig {
        dataset: "banana".into(),
        rows,
        bandwidth: 0.35,
        outlier_fraction: 0.001,
        sample_size: 6,
        seed: 7,
        ..RunConfig::default()
    };
    let data = Banana::default().generate(rows, base.seed);

    let mut table = Table::new(
        format!("Unified training engine: banana, {rows} rows"),
        &["method", "time_s", "R^2", "#SV", "iters", "conv", "smo_iters", "notes"],
    );
    for method in Method::ALL {
        let mut cfg = RunConfig { method, ..base.clone() };
        if method == Method::Incremental {
            // demo pacing: at 6000 rows a 64-update staleness budget
            // would re-solve the active set every 32 slides; spread the
            // forced resyncs out and let divergence checks drive the rest
            cfg.stale_budget = 1024;
        }
        let engine = Engine::from_config(&cfg).expect("config must validate");
        let report = engine.train(&data).expect("training must succeed");
        table.row(vec![
            method.name().into(),
            f(report.seconds, 3),
            f(report.model.r2(), 4),
            i(report.model.num_sv()),
            i(report.iterations),
            report.converged.to_string(),
            i(report.solver.smo_iterations),
            report.extras_line(),
        ]);
    }
    println!("{}", table.render());
    println!(
        "All methods agree on the description up to sampling noise: \
         the paper's point, now one trait away."
    );
}
