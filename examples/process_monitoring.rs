//! End-to-end driver (EXPERIMENTS.md section E2E): equipment-health
//! monitoring on the Tennessee-Eastman-like process — the application
//! the paper's introduction motivates.
//!
//! Pipeline exercised, all three layers composing:
//!   1. simulate the 41-variable plant (L3 substrate),
//!   2. train the one-class description of normal operations with the
//!      paper's sampling method, routing every sample/union gram matrix
//!      through the **AOT Pallas gram artifact** (L1/L2 via PJRT),
//!   3. serve a scoring stream of normal + 20 fault modes through the
//!      **AOT Pallas scoring artifact**, batched,
//!   4. report detection quality per fault family + latency/throughput.
//!
//! Run after `make artifacts`: `cargo run --release --example process_monitoring`

use std::path::Path;

use fastsvdd::data::tennessee::{fault_kind, FaultKind, TennesseePlant, DIM, NUM_FAULTS};
use fastsvdd::metrics::Metrics;
use fastsvdd::runtime::SharedRuntime;
use fastsvdd::sampling::{SamplingConfig, SamplingTrainer};
use fastsvdd::scoring::{F1Score, Scorer};
use fastsvdd::svdd::bandwidth::median_heuristic;
use fastsvdd::svdd::SvddParams;
use fastsvdd::util::timer::{fmt_duration, Stopwatch};

fn main() -> fastsvdd::Result<()> {
    let plant = TennesseePlant::default();

    // trace the whole run: every train iteration, SMO solve, gram
    // panel and batch score lands in the in-process ring, rendered as
    // a per-stage report at the end (same pipeline as
    // `fastsvdd train --log-json` + `fastsvdd report`)
    fastsvdd::obs::enable();

    // ---- train on normal operations ----
    let train_rows = 20_000;
    let train = plant.training(train_rows, 42);
    let bw = median_heuristic(&train, 20_000, 1);
    let params = SvddParams::gaussian(bw, 0.005);
    let cfg = SamplingConfig { sample_size: DIM + 1, ..Default::default() };

    let runtime = SharedRuntime::new(Path::new("artifacts")).ok();
    let sw = Stopwatch::start();
    let outcome = match &runtime {
        Some(rt) => SamplingTrainer::new(params, cfg).with_backend(rt).train(&train, 7)?,
        None => SamplingTrainer::new(params, cfg).train(&train, 7)?,
    };
    let t_train = sw.elapsed_secs();
    println!(
        "trained on {train_rows} normal observations in {} ({} iterations, {} SVs, gram via {})",
        fmt_duration(t_train),
        outcome.iterations,
        outcome.model.num_sv(),
        if runtime.is_some() { "XLA/Pallas artifact" } else { "native kernels" },
    );

    // ---- serve the monitoring stream ----
    let metrics = Metrics::new();
    let scorer = match &runtime {
        Some(rt) => Scorer::xla(&outcome.model, rt),
        None => Scorer::native(&outcome.model),
    };
    println!(
        "serving with the {} scoring engine",
        if scorer.is_accelerated() { "XLA/Pallas" } else { "native" }
    );

    // per-fault detection: skip the first 100 rows (faults develop)
    println!("\n{:>6} {:>12} {:>10}", "fault", "family", "detect%");
    let mut by_family: std::collections::BTreeMap<&str, (usize, usize)> = Default::default();
    for id in 1..=NUM_FAULTS {
        let stream = plant.simulate(600, Some(id), 1000 + id as u64);
        let sw = Stopwatch::start();
        let flags = scorer.label_batch(&stream)?;
        metrics.score_latency.observe(sw.elapsed_secs());
        metrics.batches_scored.inc();
        metrics.rows_scored.add(stream.rows() as u64);
        let detected = flags[100..].iter().filter(|&&f| f).count();
        let total = flags.len() - 100;
        let family = match fault_kind(id) {
            FaultKind::Step => "step",
            FaultKind::Drift => "drift",
            FaultKind::Bias => "bias",
            FaultKind::Oscillation => "oscillation",
            FaultKind::Variance => "variance",
        };
        let e = by_family.entry(family).or_default();
        e.0 += detected;
        e.1 += total;
        println!("{id:>6} {family:>12} {:>9.1}%", 100.0 * detected as f64 / total as f64);
    }
    println!("\nper-family detection:");
    for (family, (d, t)) in &by_family {
        println!("  {family:>12}: {:.1}%", 100.0 * *d as f64 / *t as f64);
    }

    // false alarms on fresh normal data
    let normal = plant.simulate(5000, None, 77);
    let sw = Stopwatch::start();
    let flags = scorer.label_batch(&normal)?;
    let t_score = sw.elapsed_secs();
    metrics.batches_scored.inc();
    metrics.rows_scored.add(normal.rows() as u64);
    let fa = flags.iter().filter(|&&f| f).count();
    println!(
        "\nfalse alarms: {fa}/5000 = {:.2}% (f = 0.5% by construction)",
        100.0 * fa as f64 / 5000.0
    );
    println!(
        "scoring throughput: {:.0} rows/s ({} for 5000 rows)",
        5000.0 / t_score,
        fmt_duration(t_score)
    );

    // combined F1 on a labeled mix (the paper's Fig 11 metric)
    let labeled = plant.scoring(5000, 5000, 5);
    let inside = scorer.inside_batch(&labeled.data)?;
    let f1 = F1Score::compute(&labeled.labels, &inside);
    println!(
        "mixed-stream F1 (normal-as-positive): precision={:.3} recall={:.3} F1={:.3}",
        f1.precision, f1.recall, f1.f1
    );
    println!("\nmetrics: {}", metrics.render());

    // ---- per-stage observability report from the traced run ----
    fastsvdd::obs::disable();
    let jsonl: String = fastsvdd::obs::drain()
        .iter()
        .map(|ev| format!("{}\n", ev.to_json()))
        .collect();
    let report = fastsvdd::obs::report::parse(&jsonl)?;
    println!("\n{}", fastsvdd::obs::report::render(&report));
    Ok(())
}
