//! End-to-end model lifecycle on the Tennessee-Eastman-like plant:
//! drift → warm-start retrain → versioned registry promote → zero-
//! downtime hot-swap — the production loop the paper's conclusion
//! motivates ("fast periodic training using large data sets").
//!
//! The loop exercised:
//!   1. train v1 on normal operations, publish + promote it into a
//!      content-addressed registry (`fastsvdd train --registry`),
//!   2. serve v1 over TCP while background clients score continuously,
//!   3. a `StreamingSvdd` drift monitor watches a stream whose
//!      operating point has shifted (TE fault 1, a step disturbance)
//!      and reports `Drifted`,
//!   4. the `Lifecycle` driver retrains *warm* (SV* seeded from the
//!      champion), publishes v2, promotes it and hot-swaps the serving
//!      slot — the clients never see an error,
//!   5. the operator lists the registry and rolls back to v1, again
//!      without a restart.
//!
//! Run: `cargo run --release --example lifecycle_monitoring`

use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::Arc;
use std::time::Duration;

use fastsvdd::data::tennessee::{TennesseePlant, DIM};
use fastsvdd::registry::{Lifecycle, Registry};
use fastsvdd::sampling::{SamplingConfig, StreamingConfig, StreamingSvdd};
use fastsvdd::scoring::{BatchPolicy, ScoreClient, ScoreServer};
use fastsvdd::svdd::bandwidth::median_heuristic;
use fastsvdd::svdd::SvddParams;
use fastsvdd::util::timer::fmt_duration;

fn main() -> fastsvdd::Result<()> {
    let plant = TennesseePlant::default();
    let registry_dir = std::env::temp_dir().join(format!(
        "fastsvdd_lifecycle_demo_{}",
        std::process::id()
    ));
    std::fs::remove_dir_all(&registry_dir).ok();

    // ---- v1: train on normal operations, publish + promote ----
    let normal = plant.training(8_000, 42);
    let bw = median_heuristic(&normal, 8_000, 1);
    let params = SvddParams::gaussian(bw, 0.005);
    let cfg = SamplingConfig { sample_size: DIM + 1, ..Default::default() };
    let mut lifecycle = Lifecycle::new(Registry::open(&registry_dir)?, params, cfg);
    let v1 = lifecycle.retrain(&normal, 7)?;
    println!(
        "v1 {} promoted: R^2={:.4}, {} iterations (cold start), {}",
        v1.id,
        v1.r2,
        v1.iterations,
        fmt_duration(v1.seconds)
    );

    // ---- serve the champion; hand the slot to the lifecycle ----
    let (_, champion) = lifecycle.registry().champion_model()?.expect("just promoted");
    let server = ScoreServer::spawn(
        "127.0.0.1:0",
        champion,
        BatchPolicy::default(),
        |m, zs| Ok(m.dist2_batch(zs)),
    )?;
    lifecycle = lifecycle
        .with_slot(server.slot())
        .with_metrics(server.metrics.clone());
    println!("serving on {} (hot-swappable slot attached)", server.addr());

    // ---- background clients score the live stream throughout ----
    let stop = Arc::new(AtomicBool::new(false));
    let errors = Arc::new(AtomicU64::new(0));
    let replies = Arc::new(AtomicU64::new(0));
    let addr = server.addr();
    let clients: Vec<_> = (0..2)
        .map(|c| {
            let stop = stop.clone();
            let errors = errors.clone();
            let replies = replies.clone();
            let plant = plant.clone();
            std::thread::spawn(move || {
                let client = match ScoreClient::connect(addr) {
                    Ok(cl) => cl,
                    Err(_) => {
                        errors.fetch_add(1, Ordering::Relaxed);
                        return;
                    }
                };
                let mut seed = 900 + c as u64;
                while !stop.load(Ordering::Relaxed) {
                    let zs = plant.simulate(16, None, seed);
                    seed += 1;
                    match client.score(&zs) {
                        Ok(_) => {
                            replies.fetch_add(1, Ordering::Relaxed);
                        }
                        Err(_) => {
                            errors.fetch_add(1, Ordering::Relaxed);
                        }
                    }
                }
                client.close();
            })
        })
        .collect();

    // ---- drift monitor sees the operating point shift (fault 1) ----
    let monitor_cfg = StreamingConfig {
        window: 256,
        sample_size: DIM + 1,
        drift_threshold: 0.05,
        drift_patience: 2,
        ..Default::default()
    };
    let mut monitor = StreamingSvdd::new(params, monitor_cfg, 11);
    let _ = monitor.push_batch(&plant.simulate(1_024, None, 77))?;
    println!("\nstreaming a step-disturbance regime (TE fault 1) into the monitor...");
    let drifted_stream = plant.simulate(4_096, Some(1), 78);
    let mut v2 = None;
    for i in 0..drifted_stream.rows() {
        if let Some(status) = monitor.push(drifted_stream.row(i))? {
            println!("  window update {:2}: {status:?}", monitor.updates());
            if let Some(report) = lifecycle.observe(status, &drifted_stream, 13)? {
                v2 = Some(report);
                break;
            }
        }
    }
    let v2 = match v2 {
        Some(report) => report,
        None => {
            println!("(monitor stayed stable; retraining on the new regime anyway)");
            lifecycle.retrain(&drifted_stream, 13)?
        }
    };
    // judge future windows against the fresh champion
    monitor.adopt_model(lifecycle.registry().load(&v2.id)?)?;
    println!(
        "v2 {} promoted + hot-swapped (epoch {:?}): R^2={:.4}, {} iterations ({} start), {}",
        v2.id,
        v2.epoch,
        v2.r2,
        v2.iterations,
        if v2.warm_start { "warm" } else { "cold" },
        fmt_duration(v2.seconds)
    );
    println!(
        "warm-start retrain: {} iterations vs {} for the cold start",
        v2.iterations, v1.iterations
    );

    // let the clients score against v2, then stop them
    std::thread::sleep(Duration::from_millis(200));
    stop.store(true, Ordering::Relaxed);
    for t in clients {
        t.join().ok();
    }
    println!(
        "clients across the swap: {} replies, {} errors (zero-downtime claim)",
        replies.load(Ordering::Relaxed),
        errors.load(Ordering::Relaxed)
    );

    let probe = ScoreClient::connect(addr)?;
    let info = probe.model_info()?;
    println!(
        "server reports model {} (epoch {}), R^2={:.4}",
        info.version, info.epoch, info.r2
    );

    // ---- the operator's view: registry list + rollback ----
    println!(
        "\nregistry contents (= fastsvdd registry list --dir {}):",
        registry_dir.display()
    );
    let champ = lifecycle.registry().champion()?.map(|e| e.id);
    for e in lifecycle.registry().list()? {
        println!(
            "  {} {} R^2={:.4} #SV={} rows={} iters={} {}",
            e.id,
            if Some(&e.id) == champ.as_ref() { "*" } else { " " },
            e.meta.r2,
            e.meta.num_sv,
            e.meta.rows,
            e.meta.iterations,
            if e.meta.warm_start { "warm" } else { "cold" }
        );
    }

    let back = lifecycle.rollback()?;
    let info = probe.model_info()?;
    println!(
        "\nrolled back to {back}; server now reports {} (epoch {}) — no restart",
        info.version, info.epoch
    );
    probe.close();

    println!("\nmetrics: {}", server.metrics.render());
    drop(server);
    std::fs::remove_dir_all(&registry_dir).ok();
    Ok(())
}
