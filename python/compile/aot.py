"""AOT export: lower the L2 graphs to HLO *text* artifacts.

Run once at build time (``make artifacts``)::

    cd python && python -m compile.aot --out ../artifacts

Interchange format is HLO text, NOT a serialized HloModuleProto: jax
>= 0.5 emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects (``proto.id() <= INT_MAX``); the text parser
reassigns ids and round-trips cleanly. Lowered with ``return_tuple=True``
so the Rust runtime unwraps with ``to_tuple1``.

One module per static-shape bucket. The bucket set covers the paper's
three regimes: m=2 (Banana/Star/Two-Donut/polygons), m=9 (Shuttle),
m=41 (Tennessee Eastman). A manifest JSON indexes the artifacts so the
Rust ``runtime::ArtifactRegistry`` discovers them without rebuilding.
"""

from __future__ import annotations

import argparse
import hashlib
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from compile import model

# (name-fragment, feature dims). Buckets must stay in sync with
# rust/src/runtime/artifacts.rs (the Rust side reads the manifest, so
# adding a bucket here is enough).
FEATURE_DIMS = (2, 9, 41)
SV_PAD = 512  # scoring bucket SV capacity (padded, alpha=0 beyond #SV)
SCORE_BATCHES = (256, 4096)  # latency + throughput buckets
GRAM_N = 64  # sample-gram bucket (Algorithm-1 unions are a few dozen rows)


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (see module docstring)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def f32(*shape):
    return jax.ShapeDtypeStruct(shape, jnp.float32)


def lower_score(m: int, s: int, b: int) -> str:
    lowered = jax.jit(model.score_batch).lower(
        f32(b, m), f32(s, m), f32(s), f32(1), f32(1)
    )
    return to_hlo_text(lowered)


def lower_gram(n: int, m: int) -> str:
    lowered = jax.jit(model.gram).lower(f32(n, m), f32(1))
    return to_hlo_text(lowered)


def export_all(out_dir: str) -> list[dict]:
    os.makedirs(out_dir, exist_ok=True)
    entries: list[dict] = []

    def emit(name: str, kind: str, text: str, **meta):
        path = os.path.join(out_dir, f"{name}.hlo.txt")
        with open(path, "w") as f:
            f.write(text)
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        entries.append(
            {
                "name": name,
                "kind": kind,
                "file": f"{name}.hlo.txt",
                "sha256_16": digest,
                **meta,
            }
        )
        print(f"  wrote {path} ({len(text)} chars)")

    for m in FEATURE_DIMS:
        for b in SCORE_BATCHES:
            name = f"score_m{m}_s{SV_PAD}_b{b}"
            emit(name, "score", lower_score(m, SV_PAD, b), m=m, s=SV_PAD, b=b)
        name = f"gram_n{GRAM_N}_m{m}"
        emit(name, "gram", lower_gram(GRAM_N, m), n=GRAM_N, m=m)

    manifest = {
        "version": 1,
        "sv_pad": SV_PAD,
        "gram_n": GRAM_N,
        "entries": entries,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"  wrote {out_dir}/manifest.json ({len(entries)} artifacts)")
    return entries


def main() -> None:
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out", default="../artifacts", help="output directory")
    args = ap.parse_args()
    print(f"AOT export -> {args.out}")
    export_all(args.out)


if __name__ == "__main__":
    main()
