"""L1 Pallas kernel: Gaussian gram matrix for the sample SVDD solve.

Each iteration of the paper's Algorithm 1 solves a small QP whose data is
the gram matrix K(S_i', S_i') of the union sample. The Rust SMO solver
consumes that matrix; this kernel produces it. Samples are tiny (the
paper's sweet spot is n in [5, 15], unions a few dozen rows), so the AOT
bucket pads to N = 64 and the Rust side reads the top-left n x n block —
padding rows produce garbage kernel values that are simply never read.

The grid walks row-tiles; the full X block stays resident in VMEM (64 x m
f32 is at most 64 * 41 * 4 B = 10.5 KB). Cross term on the MXU, exp on
the VPU, symmetric output written tile-row-at-a-time.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# The gram bucket is 64 rows; one grid step covers 32 rows so the kernel
# exercises a non-trivial (2-step) grid even at the smallest bucket.
TILE_R = 32


def _gram_kernel(x_ref, xt_ref, bw_ref, out_ref):
    """One grid step: rows [i*TILE_R, (i+1)*TILE_R) of K(X, X)."""
    xr = x_ref[...]  # (TILE_R, m) row slab
    xa = xt_ref[...]  # (N, m) full block, resident
    bw = bw_ref[0]

    rn = jnp.sum(xr * xr, axis=1, keepdims=True)  # (TILE_R, 1)
    an = jnp.sum(xa * xa, axis=1)[None, :]  # (1, N)
    cross = jnp.dot(xr, xa.T, preferred_element_type=jnp.float32)
    d2 = jnp.maximum(rn + an - 2.0 * cross, 0.0)
    out_ref[...] = jnp.exp(-d2 / (2.0 * bw * bw))


@functools.partial(jax.jit, static_argnames=("interpret",))
def gaussian_gram(x, bw, *, interpret: bool = True):
    """Pallas-tiled K(X, X) for the Gaussian kernel.

    x: (N, m) with N a multiple of TILE_R; bw: shape-(1,) f32.
    Returns (N, N) f32, symmetric up to float round-off.
    """
    n, m = x.shape
    if n % TILE_R != 0:
        raise ValueError(f"rows {n} not a multiple of TILE_R={TILE_R}")
    grid = (n // TILE_R,)
    return pl.pallas_call(
        _gram_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_R, m), lambda i: (i, 0)),  # row slab
            pl.BlockSpec((n, m), lambda i: (0, 0)),  # full X resident
            pl.BlockSpec((1,), lambda i: (0,)),  # bw
        ],
        out_specs=pl.BlockSpec((TILE_R, n), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, n), jnp.float32),
        interpret=interpret,
    )(x, x, bw)
