"""Pure-jnp reference oracle for the Pallas kernels.

Everything in this module is the *specification*: the Pallas kernels in
``gaussian_score.py`` / ``gaussian_gram.py`` must match these functions to
float32 tolerance for every shape/dtype the AOT buckets cover. The pytest
suite (``python/tests/test_kernels.py``) sweeps shapes with hypothesis and
asserts allclose against this module.

Math (paper eq. (13), (18)):

    K(a, b)   = exp(-||a - b||^2 / (2 s^2))
    dist2(z)  = K(z, z) - 2 sum_i alpha_i K(x_i, z) + W
              = 1 - 2 k(z)^T alpha + W          (Gaussian => K(z,z)=1)

where ``W = alpha^T K(SV, SV) alpha`` is a per-model constant that the
caller precomputes once (the Rust coordinator does this at model-build
time, so the scoring graph never recomputes the SV x SV gram).
"""

from __future__ import annotations

import jax.numpy as jnp


def sqdist(a: jnp.ndarray, b: jnp.ndarray) -> jnp.ndarray:
    """Pairwise squared euclidean distances.

    a: (n, m), b: (k, m)  ->  (n, k).

    Uses the expanded form ||a||^2 + ||b||^2 - 2 a.b^T (same algebra the
    Pallas kernel uses on the MXU) clamped at zero to kill negative
    round-off.
    """
    an = jnp.sum(a * a, axis=1, keepdims=True)  # (n, 1)
    bn = jnp.sum(b * b, axis=1)[None, :]  # (1, k)
    d2 = an + bn - 2.0 * (a @ b.T)
    return jnp.maximum(d2, 0.0)


def gaussian_gram(a: jnp.ndarray, b: jnp.ndarray, bw) -> jnp.ndarray:
    """Gaussian kernel matrix K[i, j] = exp(-||a_i - b_j||^2 / (2 bw^2))."""
    return jnp.exp(-sqdist(a, b) / (2.0 * bw * bw))


def svdd_dist2(
    z: jnp.ndarray, sv: jnp.ndarray, alpha: jnp.ndarray, bw, w
) -> jnp.ndarray:
    """Kernel distance-to-center squared for each row of ``z``.

    z: (b, m) scoring batch; sv: (s, m) support vectors (padded rows carry
    alpha = 0 and therefore drop out); alpha: (s,); bw scalar bandwidth;
    w scalar = alpha^T K(sv, sv) alpha. Returns (b,) float32.
    """
    k = gaussian_gram(z, sv, bw)  # (b, s)
    return 1.0 - 2.0 * (k @ alpha) + w


def svdd_w(sv: jnp.ndarray, alpha: jnp.ndarray, bw) -> jnp.ndarray:
    """The model constant W = alpha^T K(SV, SV) alpha."""
    return alpha @ gaussian_gram(sv, sv, bw) @ alpha
