"""L1 Pallas kernel: batched SVDD kernel-distance scoring.

The scoring hot spot of the paper — eq. (18) — evaluated for a batch of
observations Z against the (padded) master support-vector set:

    dist2[b] = 1 - 2 * sum_s alpha[s] * exp(-||Z[b] - SV[s]||^2 / 2 bw^2) + W

TPU mapping (DESIGN.md section "Hardware adaptation"): the cross term
``Z_tile @ SV^T`` is an MXU matmul; norms, exp and the alpha-weighted
reduction fuse on the VPU. The grid walks row-tiles of Z; the SV block
(<= 512 x m, a few hundred KB) stays resident in VMEM across the whole
grid, so HBM traffic is one pass over Z plus one fetch of SV.

We run under ``interpret=True`` everywhere in this session: the CPU PJRT
plugin cannot execute Mosaic custom-calls, and interpret mode lowers the
kernel to plain HLO that the Rust runtime's PJRT CPU client executes
directly. The BlockSpec schedule is unchanged, so the VMEM/MXU analysis
in DESIGN.md section 9 still describes the real-TPU behaviour.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Row-tile of the scoring batch. 128 keeps the f32 cross-term tile
# (TILE_B x S = 128 x 512 x 4B = 256 KB) comfortably inside VMEM next to
# the resident SV block, and is a multiple of the 8x128 VPU lane shape.
TILE_B = 128


def _score_kernel(z_ref, sv_ref, alpha_ref, bw_ref, w_ref, out_ref):
    """One grid step: score a (TILE_B, m) slab of Z against all SVs."""
    z = z_ref[...]  # (TILE_B, m)   VMEM
    sv = sv_ref[...]  # (S, m)        VMEM, resident
    alpha = alpha_ref[...]  # (S,)
    bw = bw_ref[0]
    w = w_ref[0]

    zn = jnp.sum(z * z, axis=1, keepdims=True)  # (TILE_B, 1)  VPU
    xn = jnp.sum(sv * sv, axis=1)[None, :]  # (1, S)       VPU
    # MXU: the only O(TILE_B * S * m) term.
    cross = jnp.dot(z, sv.T, preferred_element_type=jnp.float32)
    d2 = jnp.maximum(zn + xn - 2.0 * cross, 0.0)  # (TILE_B, S)
    k = jnp.exp(-d2 / (2.0 * bw * bw))
    # alpha-weighted reduction collapses S in-register; padded SV rows
    # carry alpha = 0 and vanish here.
    out_ref[...] = 1.0 - 2.0 * jnp.dot(k, alpha) + w


@functools.partial(jax.jit, static_argnames=("interpret",))
def svdd_score(z, sv, alpha, bw, w, *, interpret: bool = True):
    """Pallas-tiled SVDD scoring.

    z: (B, m) with B a multiple of TILE_B (the AOT buckets guarantee it;
    the Rust caller pads the final batch). sv: (S, m); alpha: (S,);
    bw, w: shape-(1,) f32 scalars. Returns dist2: (B,) f32.
    """
    b, m = z.shape
    s, m2 = sv.shape
    if m != m2:
        raise ValueError(f"dim mismatch: z has m={m}, sv has m={m2}")
    if b % TILE_B != 0:
        raise ValueError(f"batch {b} not a multiple of TILE_B={TILE_B}")
    grid = (b // TILE_B,)
    return pl.pallas_call(
        _score_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((TILE_B, m), lambda i: (i, 0)),  # stream Z tiles
            pl.BlockSpec((s, m), lambda i: (0, 0)),  # SV resident
            pl.BlockSpec((s,), lambda i: (0,)),  # alpha resident
            pl.BlockSpec((1,), lambda i: (0,)),  # bw
            pl.BlockSpec((1,), lambda i: (0,)),  # w
        ],
        out_specs=pl.BlockSpec((TILE_B,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((b,), jnp.float32),
        interpret=interpret,
    )(z, sv, alpha, bw, w)
