"""L2: the JAX compute graphs the Rust coordinator executes via PJRT.

Two graphs, both built on the L1 Pallas kernels:

- ``score_batch`` — paper eq. (18): kernel distance of a scoring batch to
  the model center. This is the serve-path graph (grid scoring, F1
  evaluation, outlier streams).
- ``gram`` — K(X, X) of a (padded) union sample, the input to the Rust
  SMO solve inside each Algorithm-1 iteration.

Shapes are static per AOT bucket (see ``aot.py``); the Rust side pads
batches / SV sets up to the bucket and masks results. Nothing in this
module runs at serve time — ``make artifacts`` lowers these functions to
HLO text once, and the Rust runtime loads the text.
"""

from __future__ import annotations

import jax.numpy as jnp

from compile.kernels.gaussian_gram import gaussian_gram
from compile.kernels.gaussian_score import svdd_score


def score_batch(z, sv, alpha, bw, w):
    """dist2 for each row of z. All inputs f32; bw/w are shape-(1,).

    Returns a 1-tuple so the HLO entry computation is a tuple and the
    Rust side can unwrap with ``to_tuple1`` (see aot_recipe / gen_hlo).
    """
    return (svdd_score(z, sv, alpha, bw, w),)


def gram(x, bw):
    """K(X, X) of the padded sample block. Returns a 1-tuple (see above)."""
    return (gaussian_gram(x, bw),)


def score_batch_ref(z, sv, alpha, bw, w):
    """Pure-jnp L2 graph (no Pallas), kept for A/B in tests and perf work."""
    from compile.kernels import ref

    return (ref.svdd_dist2(z, sv, alpha, bw[0], w[0]).astype(jnp.float32),)
