"""AOT export tests: every bucket lowers to parseable HLO text with the
expected entry layout, and the manifest indexes all artifacts."""

import json
import os
import re

import pytest

from compile import aot


@pytest.fixture(scope="module")
def exported(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    entries = aot.export_all(str(out))
    return str(out), entries


def test_all_buckets_exported(exported):
    out, entries = exported
    names = {e["name"] for e in entries}
    for m in aot.FEATURE_DIMS:
        for b in aot.SCORE_BATCHES:
            assert f"score_m{m}_s{aot.SV_PAD}_b{b}" in names
        assert f"gram_n{aot.GRAM_N}_m{m}" in names
    assert len(entries) == len(aot.FEATURE_DIMS) * (len(aot.SCORE_BATCHES) + 1)


def test_hlo_text_shape_contract(exported):
    out, entries = exported
    for e in entries:
        text = open(os.path.join(out, e["file"])).read()
        assert text.startswith("HloModule"), e["name"]
        m = re.search(r"entry_computation_layout=\{\((.*?)\)->", text)
        assert m, f"no entry layout in {e['name']}"
        params = m.group(1)
        if e["kind"] == "score":
            assert f"f32[{e['b']},{e['m']}]" in params  # z
            assert f"f32[{e['s']},{e['m']}]" in params  # sv
            assert f"f32[{e['b']}]" in text.split("->")[1].split("}")[0]
        else:
            assert f"f32[{aot.GRAM_N},{e['m']}]" in params


def test_hlo_output_is_tuple(exported):
    """return_tuple=True contract: entry returns (f32[...]) as a tuple."""
    out, entries = exported
    for e in entries:
        text = open(os.path.join(out, e["file"])).read()
        layout = re.search(r"entry_computation_layout=\{\(.*?\)->\((.*?)\)\}", text)
        assert layout, e["name"]


def test_manifest_roundtrip(exported):
    out, entries = exported
    man = json.load(open(os.path.join(out, "manifest.json")))
    assert man["version"] == 1
    assert man["sv_pad"] == aot.SV_PAD
    assert {e["name"] for e in man["entries"]} == {e["name"] for e in entries}
    for e in man["entries"]:
        assert os.path.exists(os.path.join(out, e["file"]))
        assert len(e["sha256_16"]) == 16
