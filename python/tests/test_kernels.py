"""L1 correctness: Pallas kernels vs the pure-jnp oracle (ref.py).

Hypothesis sweeps shapes (within the bucket-compatible lattice: batch a
multiple of TILE_B, gram rows a multiple of TILE_R) and data scales;
assert_allclose against ref.py is the core correctness signal for the
whole stack — the Rust runtime executes exactly these lowered graphs.
"""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax
import jax.numpy as jnp

from compile.kernels import ref
from compile.kernels.gaussian_gram import TILE_R, gaussian_gram
from compile.kernels.gaussian_score import TILE_B, svdd_score

jax.config.update("jax_platform_name", "cpu")


def rng(seed):
    return np.random.default_rng(seed)


def make_model(r, s, m, n_real=None):
    """A random padded SVDD model: sv, alpha (zero beyond n_real), bw, w."""
    sv = r.normal(size=(s, m)).astype(np.float32)
    n_real = s if n_real is None else n_real
    alpha = np.zeros(s, dtype=np.float32)
    a = r.uniform(0.1, 1.0, size=n_real).astype(np.float32)
    alpha[:n_real] = a / a.sum()
    bw = np.float32(r.uniform(0.5, 3.0))
    w = float(ref.svdd_w(jnp.asarray(sv), jnp.asarray(alpha), bw))
    return sv, alpha, bw, np.float32(w)


# ---------------------------------------------------------------- score


@settings(max_examples=25, deadline=None)
@given(
    bt=st.integers(1, 3),
    m=st.sampled_from([1, 2, 3, 9, 17, 41]),
    s=st.sampled_from([8, 64, 512]),
    n_real=st.integers(1, 8),
    seed=st.integers(0, 2**31 - 1),
)
def test_score_matches_ref(bt, m, s, n_real, seed):
    r = rng(seed)
    b = bt * TILE_B
    z = r.normal(size=(b, m)).astype(np.float32) * 2.0
    sv, alpha, bw, w = make_model(r, s, m, n_real=min(n_real, s))
    got = np.asarray(
        svdd_score(z, sv, alpha, np.array([bw]), np.array([w]))
    )
    want = np.asarray(ref.svdd_dist2(z, sv, alpha, bw, w))
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_score_padding_rows_are_inert():
    """Extra SV rows with alpha=0 must not change any score."""
    r = rng(7)
    m = 2
    z = r.normal(size=(TILE_B, m)).astype(np.float32)
    sv8, alpha8, bw, _ = make_model(r, 8, m)
    w = np.float32(ref.svdd_w(jnp.asarray(sv8), jnp.asarray(alpha8), bw))
    base = np.asarray(
        svdd_score(z, sv8, alpha8, np.array([bw]), np.array([w]))
    )
    # pad to 64 with huge garbage coordinates but alpha = 0
    sv64 = np.full((64, m), 1e6, dtype=np.float32)
    sv64[:8] = sv8
    alpha64 = np.zeros(64, dtype=np.float32)
    alpha64[:8] = alpha8
    padded = np.asarray(
        svdd_score(z, sv64, alpha64, np.array([bw]), np.array([w]))
    )
    np.testing.assert_allclose(padded, base, rtol=1e-6, atol=1e-6)


def test_score_self_point_is_inside():
    """A training point that IS the single SV has dist2 = 1 - 2 + 1 = 0."""
    m = 3
    sv = np.zeros((8, m), dtype=np.float32)
    alpha = np.zeros(8, dtype=np.float32)
    alpha[0] = 1.0
    bw = np.float32(1.0)
    w = np.float32(ref.svdd_w(jnp.asarray(sv), jnp.asarray(alpha), bw))
    z = np.zeros((TILE_B, m), dtype=np.float32)
    got = np.asarray(svdd_score(z, sv, alpha, np.array([bw]), np.array([w])))
    np.testing.assert_allclose(got, np.zeros(TILE_B), atol=1e-6)


def test_score_monotone_in_distance():
    """dist2 increases as z moves away from a single-SV center."""
    m = 2
    sv = np.zeros((8, m), dtype=np.float32)
    alpha = np.zeros(8, dtype=np.float32)
    alpha[0] = 1.0
    bw = np.float32(1.0)
    w = np.float32(1.0)  # K(0,0) = 1
    z = np.zeros((TILE_B, m), dtype=np.float32)
    z[:, 0] = np.linspace(0, 5, TILE_B)
    got = np.asarray(svdd_score(z, sv, alpha, np.array([bw]), np.array([w])))
    assert np.all(np.diff(got) > 0)


def test_score_rejects_bad_batch():
    with pytest.raises(ValueError):
        svdd_score(
            np.zeros((100, 2), np.float32),
            np.zeros((8, 2), np.float32),
            np.zeros(8, np.float32),
            np.array([1.0], np.float32),
            np.array([1.0], np.float32),
        )


def test_score_rejects_dim_mismatch():
    with pytest.raises(ValueError):
        svdd_score(
            np.zeros((TILE_B, 3), np.float32),
            np.zeros((8, 2), np.float32),
            np.zeros(8, np.float32),
            np.array([1.0], np.float32),
            np.array([1.0], np.float32),
        )


# ----------------------------------------------------------------- gram


@settings(max_examples=25, deadline=None)
@given(
    nt=st.integers(1, 4),
    m=st.sampled_from([1, 2, 5, 9, 41]),
    scale=st.floats(0.1, 10.0),
    seed=st.integers(0, 2**31 - 1),
)
def test_gram_matches_ref(nt, m, scale, seed):
    r = rng(seed)
    n = nt * TILE_R
    x = (r.normal(size=(n, m)) * scale).astype(np.float32)
    bw = np.float32(r.uniform(0.3, 4.0))
    got = np.asarray(gaussian_gram(x, np.array([bw])))
    want = np.asarray(ref.gaussian_gram(x, x, bw))
    # Both sides are f32 expanded-form distances but reduce in different
    # orders; the cancellation error in d2 is O(||x||^2 * 1e-7) and gets
    # amplified by exp(.../2bw^2), so the tolerance must scale with the
    # data norm (scale <= 10, m <= 41, bw >= 0.3 -> ~1e-3 worst case).
    np.testing.assert_allclose(got, want, rtol=2e-3, atol=2e-3)


def test_gram_diagonal_is_one():
    r = rng(3)
    x = r.normal(size=(TILE_R * 2, 9)).astype(np.float32)
    k = np.asarray(gaussian_gram(x, np.array([1.5], np.float32)))
    np.testing.assert_allclose(np.diag(k), np.ones(len(x)), atol=1e-6)


def test_gram_symmetric():
    r = rng(4)
    x = r.normal(size=(TILE_R, 5)).astype(np.float32)
    k = np.asarray(gaussian_gram(x, np.array([0.8], np.float32)))
    np.testing.assert_allclose(k, k.T, atol=1e-5)


def test_gram_values_in_unit_interval():
    r = rng(5)
    x = (r.normal(size=(TILE_R, 3)) * 50).astype(np.float32)
    k = np.asarray(gaussian_gram(x, np.array([0.5], np.float32)))
    assert np.all(k >= 0.0) and np.all(k <= 1.0 + 1e-6)


def test_gram_bandwidth_limit_behaviour():
    """bw -> inf: K -> all-ones. bw -> 0: K -> identity."""
    r = rng(6)
    x = r.normal(size=(TILE_R, 4)).astype(np.float32)
    k_wide = np.asarray(gaussian_gram(x, np.array([1e4], np.float32)))
    np.testing.assert_allclose(k_wide, np.ones_like(k_wide), atol=1e-4)
    # bw = 1e-2 is the narrowest bandwidth the expanded-form f32 distance
    # supports: cancellation error in d2 is O(1e-6), which must stay well
    # below 2*bw^2 for exp(-d2 / 2 bw^2) to saturate correctly.
    k_narrow = np.asarray(gaussian_gram(x, np.array([1e-2], np.float32)))
    np.testing.assert_allclose(k_narrow, np.eye(len(x)), atol=1e-2)


def test_gram_rejects_bad_rows():
    with pytest.raises(ValueError):
        gaussian_gram(np.zeros((33, 2), np.float32), np.array([1.0], np.float32))
