"""L2 graph tests: score_batch / gram wrappers + pallas-vs-jnp A/B."""

import numpy as np

from compile import model
from compile.kernels import ref
from compile.kernels.gaussian_score import TILE_B


def test_score_batch_tuple_contract():
    """L2 returns a 1-tuple (the AOT contract for rust to_tuple1)."""
    z = np.zeros((TILE_B, 2), np.float32)
    sv = np.zeros((8, 2), np.float32)
    alpha = np.zeros(8, np.float32)
    alpha[0] = 1.0
    out = model.score_batch(
        z, sv, alpha, np.array([1.0], np.float32), np.array([1.0], np.float32)
    )
    assert isinstance(out, tuple) and len(out) == 1
    assert out[0].shape == (TILE_B,)
    assert out[0].dtype == np.float32


def test_gram_tuple_contract():
    x = np.zeros((64, 9), np.float32)
    out = model.gram(x, np.array([2.0], np.float32))
    assert isinstance(out, tuple) and len(out) == 1
    assert out[0].shape == (64, 64)


def test_pallas_graph_matches_jnp_graph():
    """The Pallas L2 graph and the pure-jnp L2 graph agree (A/B used in perf)."""
    r = np.random.default_rng(11)
    z = r.normal(size=(2 * TILE_B, 9)).astype(np.float32)
    sv = r.normal(size=(64, 9)).astype(np.float32)
    alpha = np.zeros(64, np.float32)
    a = r.uniform(0.2, 1.0, size=16).astype(np.float32)
    alpha[:16] = a / a.sum()
    bw = np.array([1.7], np.float32)
    w = np.array([float(ref.svdd_w(sv, alpha, bw[0]))], np.float32)
    got = np.asarray(model.score_batch(z, sv, alpha, bw, w)[0])
    want = np.asarray(model.score_batch_ref(z, sv, alpha, bw, w)[0])
    np.testing.assert_allclose(got, want, rtol=2e-5, atol=2e-5)


def test_score_decision_consistency():
    """Points far outside score above points at the center (sanity of the
    decision geometry the Rust coordinator relies on)."""
    r = np.random.default_rng(12)
    sv = r.normal(size=(32, 2)).astype(np.float32) * 0.3
    alpha = np.full(32, 1 / 32, np.float32)
    bw = np.array([1.0], np.float32)
    w = np.array([float(ref.svdd_w(sv, alpha, bw[0]))], np.float32)
    z = np.zeros((TILE_B, 2), np.float32)
    z[64:, :] = 25.0  # far away
    d = np.asarray(model.score_batch(z, sv, alpha, bw, w)[0])
    assert d[64:].min() > d[:64].max()
    # far points approach the asymptote 1 + W
    np.testing.assert_allclose(d[64:], 1.0 + w[0], atol=1e-5)
